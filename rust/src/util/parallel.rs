//! Data-parallel kernel scheduler: a lazily-started, process-wide
//! **persistent worker pool**.
//!
//! The original primitives spawned and joined fresh OS threads via
//! `std::thread::scope` on every kernel call — ~10 µs × workers × layers
//! of pure overhead per forward, which forced small layers onto the
//! serial path. This module keeps the three entry points
//! ([`parallel_for_chunks`], [`parallel_for_mut_chunks`],
//! [`parallel_for_dynamic`]) but runs them on long-lived workers parked
//! on a condvar:
//!
//! * **Lifecycle** — workers spawn on first parallel call (or eagerly via
//!   [`ensure_started`], which engines call at model-register time so the
//!   first request never pays pool bring-up) and park between jobs. Zero
//!   threads are created on the steady-state hot path ([`spawn_count`] is
//!   the test hook).
//! * **Dispatch** — the caller publishes one epoch-tagged job descriptor
//!   (range, chunk size, type-erased body) and wakes the pool; workers
//!   and the caller (participating as slot 0) claim grain-sized chunks
//!   off a shared atomic cursor. Dynamic claiming replaces the old static
//!   equal split, so `rows % nt != 0` no longer leaves one worker with a
//!   longer tail. The caller blocks until a completion count drains,
//!   which also keeps the non-`'static` borrow in the body sound.
//! * **Worker identity** — every pool worker owns a stable slot id
//!   ([`current_slot`]); kernels key their L2 A-panels and accumulators
//!   on it (`BufferPool::acquire_affine`) so each worker reacquires the
//!   same warm buffer across tiles, layers and requests. OS-level core
//!   pinning is not available in the std-only offline build; slot
//!   affinity is the logical analogue.
//! * **Isolation** — a panicking job body is caught on the worker, the
//!   job still completes on the remaining chunks, and the panic is
//!   re-raised on the caller; the pool survives (poisoned-job isolation).
//! * **Concurrency** — one job runs at a time; a second caller that finds
//!   the pool busy runs its range inline instead of queueing, so
//!   concurrent forwards always make progress and results stay
//!   bit-identical (every chunk computes the same values regardless of
//!   which thread claims it).
//!
//! `ESPRESSO_THREADS` caps the worker count (first read wins; tests and
//! benches override deterministically via [`set_num_threads_for_test`]).
//! `ESPRESSO_DISPATCH=spawn` restores the legacy spawn-per-call scheduler
//! — kept as the measured baseline for `benches/latency.rs` and selected
//! per-run via [`set_dispatch_mode_for_bench`].
//!
//! Because a pool wakeup costs ~an order of magnitude less than a thread
//! spawn, pooled dispatch also splits work about [`POOL_GRAIN_DIV`]×
//! finer than the legacy grain constants assumed profitable — that is
//! what lets batch-1 layers, which previously fell back to serial to
//! dodge spawn cost, actually use the cores.

use std::cell::Cell;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Hard cap on scheduler slots (caller slot 0 + pool workers). Bounds the
/// per-step chunk counters in [`ParallelCtx`] and keeps oversubscribed
/// configs (`ESPRESSO_THREADS` ≫ cores) from spawning without limit.
pub const MAX_WORKERS: usize = 64;

/// Pooled dispatch splits work this much finer than the legacy grain
/// constants (which priced in a ~10 µs spawn per chunk): a spin-hot
/// epoch-flip dispatch costs ~1 µs, so chunks an order of magnitude
/// smaller still amortize. This is what lets the batch-1 conv GEMMs
/// (a few hundred C rows) parallelize at all.
const POOL_GRAIN_DIV: usize = 16;

// ---------------------------------------------------------------------
// thread-count configuration
// ---------------------------------------------------------------------

static NT: AtomicUsize = AtomicUsize::new(0);

/// Number of scheduler slots (caller + workers) compute kernels use.
/// Respects `ESPRESSO_THREADS` if set, else `available_parallelism`,
/// clamped to [`MAX_WORKERS`]. Cached after the first read; override
/// deterministically with [`set_num_threads_for_test`].
pub fn num_threads() -> usize {
    let c = NT.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("ESPRESSO_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .min(MAX_WORKERS);
    // every racer computes the same value, so first-write-wins is benign
    NT.store(n, Ordering::Relaxed);
    n
}

/// Deterministic thread-count override for tests and benches: replaces
/// the cached `ESPRESSO_THREADS`/`available_parallelism` value (clamped
/// to [`MAX_WORKERS`]). The running pool resizes against it on the next
/// dispatch (or eagerly via [`ensure_started`]); shrinking leaves extra
/// workers parked — jobs simply stop including them. This is the
/// supported way to pin `num_threads()` mid-process — re-setting the env
/// var after the first read has no effect.
pub fn set_num_threads_for_test(n: usize) {
    NT.store(n.clamp(1, MAX_WORKERS), Ordering::SeqCst);
}

// ---------------------------------------------------------------------
// dispatch mode (pool vs legacy spawn-per-call baseline)
// ---------------------------------------------------------------------

/// How parallel ranges are executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Persistent worker pool, dynamic chunk claiming (the default).
    Pool,
    /// Legacy spawn-per-call scoped threads with static equal splits —
    /// retained as the measured baseline (`ESPRESSO_DISPATCH=spawn`).
    Spawn,
}

static MODE: AtomicUsize = AtomicUsize::new(0); // 0 unset, 1 pool, 2 spawn

/// Active dispatch mode (env-resolved once, overridable for benches).
pub fn dispatch_mode() -> DispatchMode {
    match MODE.load(Ordering::Relaxed) {
        1 => DispatchMode::Pool,
        2 => DispatchMode::Spawn,
        _ => {
            let m = match std::env::var("ESPRESSO_DISPATCH").as_deref() {
                Ok("spawn") => DispatchMode::Spawn,
                _ => DispatchMode::Pool,
            };
            MODE.store(
                if m == DispatchMode::Spawn { 2 } else { 1 },
                Ordering::Relaxed,
            );
            m
        }
    }
}

/// Select the dispatch mode for an A/B measurement (latency bench).
pub fn set_dispatch_mode_for_bench(m: DispatchMode) {
    MODE.store(
        match m {
            DispatchMode::Pool => 1,
            DispatchMode::Spawn => 2,
        },
        Ordering::SeqCst,
    );
}

/// Chunk size a grain resolves to under the active mode: pooled dispatch
/// splits [`POOL_GRAIN_DIV`]× finer (wakeups are that much cheaper than
/// the spawns the call-site grain constants were priced for).
fn effective_grain(grain: usize) -> usize {
    let g = grain.max(1);
    match dispatch_mode() {
        DispatchMode::Spawn => g,
        DispatchMode::Pool => (g / POOL_GRAIN_DIV).max(1),
    }
}

/// Upper bound on slots that will concurrently execute a job of `len`
/// items at this `grain` — what scratch reservations (per-worker tile
/// panels) must cover. Must agree with [`run`]'s participant count.
pub fn max_workers_for(len: usize, grain: usize) -> usize {
    if len == 0 {
        return 0;
    }
    let nt = num_threads();
    let chunk = effective_grain(grain);
    if nt <= 1 || len <= chunk {
        return 1;
    }
    nt.min(len.div_ceil(chunk))
}

// ---------------------------------------------------------------------
// global counters + per-thread identity
// ---------------------------------------------------------------------

static SPAWNS: AtomicU64 = AtomicU64::new(0);
static JOBS: AtomicU64 = AtomicU64::new(0);
static SERIAL_JOBS: AtomicU64 = AtomicU64::new(0);
static BUSY_JOBS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Scheduler slot of this thread: pool workers carry their stable id,
    /// every other thread (request/batcher/test threads submitting jobs)
    /// is slot 0 — the caller participates in its own jobs as slot 0.
    static SLOT: Cell<usize> = const { Cell::new(0) };
    /// Profiling sink installed by the plan executor for the current step.
    static CTX: Cell<*const ParallelCtx> = const { Cell::new(std::ptr::null()) };
}

/// Stable scheduler slot of the current thread (pool worker id, or 0 for
/// callers). Kernels key warm per-worker buffers on it.
pub fn current_slot() -> usize {
    SLOT.with(|s| s.get())
}

/// Total OS threads this module has ever spawned (pool growth + legacy
/// spawn-mode scoped threads). After pool warmup this must stay flat —
/// the "zero thread-spawns on the hot path" test hook.
pub fn spawn_count() -> u64 {
    SPAWNS.load(Ordering::Relaxed)
}

/// Point-in-time scheduler counters (serving metrics / `espresso profile`).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStatus {
    /// Configured slot count (`num_threads()`).
    pub threads: usize,
    /// Pool workers currently alive and parked/working.
    pub workers_alive: usize,
    /// OS threads ever spawned by the scheduler.
    pub spawned: u64,
    /// Jobs executed on the pool.
    pub jobs: u64,
    /// Jobs run inline because the range was below the parallel grain.
    pub serial_jobs: u64,
    /// Jobs run inline because another job held the pool (concurrent
    /// forwards degrade to serial instead of queueing).
    pub busy_jobs: u64,
}

/// Snapshot the scheduler counters.
pub fn pool_status() -> PoolStatus {
    PoolStatus {
        threads: num_threads(),
        workers_alive: POOL.get().map_or(0, |p| p.workers_alive.load(Ordering::Acquire)),
        spawned: SPAWNS.load(Ordering::Relaxed),
        jobs: JOBS.load(Ordering::Relaxed),
        serial_jobs: SERIAL_JOBS.load(Ordering::Relaxed),
        busy_jobs: BUSY_JOBS.load(Ordering::Relaxed),
    }
}

/// Spawn pool workers up front so the first kernel call never pays
/// bring-up: engines call this with [`num_threads`] at model-register
/// time. Idempotent; a no-op at `threads <= 1`.
pub fn ensure_started(threads: usize) {
    let t = threads.clamp(1, MAX_WORKERS);
    if t > 1 {
        pool().ensure_workers(t - 1);
    }
}

// ---------------------------------------------------------------------
// per-step profiling context
// ---------------------------------------------------------------------

/// Lock-free per-step scheduler profile: installed around a plan step via
/// [`ParallelCtx::enter`], filled in by every job the step issues —
/// chunks claimed per worker slot, job counts, and wall vs cpu spans
/// (cpu ≈ Σ participant busy time, so cpu/wall is the effective worker
/// count the step achieved).
pub struct ParallelCtx {
    /// Jobs dispatched to the pool (or legacy spawns in spawn mode).
    pub jobs: AtomicU64,
    /// Ranges run inline (below grain, single thread, or pool busy).
    pub serial: AtomicU64,
    /// Sum of parallel-job wall spans (submit → join), ns.
    pub wall_ns: AtomicU64,
    /// Sum of per-participant busy spans, ns.
    pub cpu_ns: AtomicU64,
    /// Chunks claimed per scheduler slot (0 = caller).
    pub chunks: [AtomicU64; MAX_WORKERS],
}

impl Default for ParallelCtx {
    fn default() -> Self {
        Self::new()
    }
}

impl ParallelCtx {
    pub fn new() -> Self {
        Self {
            jobs: AtomicU64::new(0),
            serial: AtomicU64::new(0),
            wall_ns: AtomicU64::new(0),
            cpu_ns: AtomicU64::new(0),
            chunks: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Route every parallel call on this thread into `self` until the
    /// guard drops (nesting restores the previous sink).
    pub fn enter(&self) -> CtxGuard<'_> {
        let prev = CTX.with(|c| c.replace(self as *const ParallelCtx));
        CtxGuard {
            prev,
            _marker: PhantomData,
        }
    }

    /// Zero all counters.
    pub fn reset(&self) {
        self.jobs.store(0, Ordering::Relaxed);
        self.serial.store(0, Ordering::Relaxed);
        self.wall_ns.store(0, Ordering::Relaxed);
        self.cpu_ns.store(0, Ordering::Relaxed);
        for c in &self.chunks {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// Copy the counters out (chunk list trimmed to the used slots).
    pub fn snapshot(&self) -> ParSnapshot {
        let mut chunks: Vec<u64> = self
            .chunks
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        while chunks.last() == Some(&0) {
            chunks.pop();
        }
        ParSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            serial: self.serial.load(Ordering::Relaxed),
            wall_ns: self.wall_ns.load(Ordering::Relaxed),
            cpu_ns: self.cpu_ns.load(Ordering::Relaxed),
            chunks,
        }
    }
}

/// Plain-data snapshot of a [`ParallelCtx`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParSnapshot {
    pub jobs: u64,
    pub serial: u64,
    pub wall_ns: u64,
    pub cpu_ns: u64,
    /// Chunks claimed per slot (index 0 = caller), zero tail trimmed.
    pub chunks: Vec<u64>,
}

impl ParSnapshot {
    /// Effective concurrent workers: Σ busy time / Σ wall time of the
    /// parallel jobs (0 when nothing ran parallel).
    pub fn utilization(&self) -> f64 {
        if self.wall_ns == 0 {
            0.0
        } else {
            self.cpu_ns as f64 / self.wall_ns as f64
        }
    }

    /// Total chunks claimed across slots.
    pub fn total_chunks(&self) -> u64 {
        self.chunks.iter().sum()
    }
}

/// RAII restore for [`ParallelCtx::enter`].
pub struct CtxGuard<'a> {
    prev: *const ParallelCtx,
    _marker: PhantomData<&'a ParallelCtx>,
}

impl Drop for CtxGuard<'_> {
    fn drop(&mut self) {
        let prev = self.prev;
        CTX.with(|c| c.set(prev));
    }
}

fn current_ctx() -> *const ParallelCtx {
    CTX.with(|c| c.get())
}

// ---------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------

/// Type-erased job descriptor, shared with workers by value. The raw
/// pointers target the submitting caller's stack; they stay valid because
/// the caller blocks until `pending` drains, and a worker's last touch of
/// job memory is its `pending` decrement.
#[derive(Clone, Copy)]
struct JobRef {
    /// Lifetime-erased borrow of the caller's body closure (see [`erase`]).
    body: &'static (dyn Fn(usize, usize) + Sync),
    cursor: *const AtomicUsize,
    pending: *const AtomicUsize,
    panicked: *const AtomicBool,
    ctx: *const ParallelCtx,
    len: usize,
    chunk: usize,
    /// Participant count including the caller (slot 0); pool workers with
    /// `id >= workers` sit this job out.
    workers: usize,
}

// SAFETY: the pointers are dereferenced only while the submitting caller
// blocks in join (see JobRef docs); ParallelCtx is all atomics.
unsafe impl Send for JobRef {}

/// Post-job spin budget (iterations) for workers whose slot fits in the
/// physical core count: kernel jobs arrive back-to-back within a forward
/// (GEMM → correction → pool → pack), so staying hot for tens of µs
/// turns the next dispatch into a sub-µs epoch-flip instead of a condvar
/// wake. Workers park after the budget, so idle serves cost nothing.
const WORKER_SPIN: u32 = 20_000;
/// Spin budget for oversubscribed workers (slot ≥ cores): they'd only
/// steal cycles from working threads, so they park almost immediately.
const WORKER_SPIN_OVERSUB: u32 = 64;
/// Caller-side join spin before parking: with grain-balanced chunks the
/// stragglers finish within ~µs of the caller, so the join almost never
/// sleeps.
const JOIN_SPIN: u32 = 5_000;

struct Pool {
    /// Bumped (under `job_m`) for every published job; workers spin on it.
    epoch: AtomicU64,
    /// The job slot; epoch and slot only change together under this lock.
    job_m: Mutex<Option<JobRef>>,
    work_cv: Condvar,
    done_m: Mutex<()>,
    done_cv: Condvar,
    /// One job at a time; competitors run inline instead of queueing.
    submit: Mutex<()>,
    /// Serializes pool growth.
    grow: Mutex<()>,
    workers_alive: AtomicUsize,
    /// Physical parallelism, for the oversubscription spin budget.
    cores: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        epoch: AtomicU64::new(0),
        job_m: Mutex::new(None),
        work_cv: Condvar::new(),
        done_m: Mutex::new(()),
        done_cv: Condvar::new(),
        submit: Mutex::new(()),
        grow: Mutex::new(()),
        workers_alive: AtomicUsize::new(0),
        cores: std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    })
}

impl Pool {
    /// Grow to `target` workers (ids `1..=target`). Idempotent.
    fn ensure_workers(&'static self, target: usize) {
        let target = target.min(MAX_WORKERS - 1);
        if self.workers_alive.load(Ordering::Acquire) >= target {
            return;
        }
        let _g = self.grow.lock().unwrap();
        let cur = self.workers_alive.load(Ordering::Acquire);
        for id in cur + 1..=target {
            std::thread::Builder::new()
                .name(format!("espresso-par-{id}"))
                .spawn(move || worker_main(pool(), id))
                .expect("spawn pool worker");
            SPAWNS.fetch_add(1, Ordering::Relaxed);
        }
        if target > cur {
            self.workers_alive.store(target, Ordering::Release);
        }
    }
}

/// Claim grain-sized chunks off the job cursor until the range drains.
fn claim_chunks(
    cursor: &AtomicUsize,
    len: usize,
    chunk: usize,
    slot: usize,
    ctx: *const ParallelCtx,
    body: &(dyn Fn(usize, usize) + Sync),
) {
    loop {
        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
        if start >= len {
            break;
        }
        let end = (start + chunk).min(len);
        if !ctx.is_null() {
            // SAFETY: ctx outlives the job (installed by the caller)
            unsafe { &*ctx }.chunks[slot.min(MAX_WORKERS - 1)].fetch_add(1, Ordering::Relaxed);
        }
        body(start, end);
    }
}

fn worker_main(pool: &'static Pool, id: usize) {
    SLOT.with(|s| s.set(id));
    let spin_budget = if id < pool.cores {
        WORKER_SPIN
    } else {
        WORKER_SPIN_OVERSUB
    };
    let mut seen = 0u64;
    loop {
        // spin phase: back-to-back kernel jobs flip the epoch within µs
        let mut spins = 0u32;
        while pool.epoch.load(Ordering::Acquire) == seen {
            spins += 1;
            if spins >= spin_budget {
                // park until the next publish (recheck under the lock so
                // a publish between the load and the wait can't be lost)
                let mut slot = pool.job_m.lock().unwrap();
                while pool.epoch.load(Ordering::Acquire) == seen {
                    slot = pool.work_cv.wait(slot).unwrap();
                }
                break;
            }
            std::hint::spin_loop();
        }
        let job = {
            // epoch and slot only change together under job_m, so this
            // pair is consistent: either the live job of `seen`, or None
            // when that job already completed without us
            let slot = pool.job_m.lock().unwrap();
            seen = pool.epoch.load(Ordering::Acquire);
            *slot
        };
        let Some(job) = job else { continue };
        if id >= job.workers {
            continue;
        }
        let t0 = Instant::now();
        // SAFETY: the submitting caller blocks until `pending` drains, so
        // every pointer in `job` is live for the whole participation; the
        // panic is contained so the worker survives poisoned bodies.
        let res = catch_unwind(AssertUnwindSafe(|| {
            claim_chunks(
                unsafe { &*job.cursor },
                job.len,
                job.chunk,
                id,
                job.ctx,
                job.body,
            );
        }));
        if res.is_err() {
            unsafe { &*job.panicked }.store(true, Ordering::Release);
        }
        if !job.ctx.is_null() {
            unsafe { &*job.ctx }
                .cpu_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        // the decrement is the last touch of job memory (see JobRef)
        let pending = unsafe { &*job.pending };
        if pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = pool.done_m.lock().unwrap();
            pool.done_cv.notify_all();
        }
    }
}

/// Erase the body's borrow so it can sit in the static job slot; sound
/// because the caller joins the job before returning, and workers never
/// touch the body after their completion decrement.
unsafe fn erase<'a>(
    body: &'a (dyn Fn(usize, usize) + Sync),
) -> &'static (dyn Fn(usize, usize) + Sync) {
    std::mem::transmute::<
        &'a (dyn Fn(usize, usize) + Sync),
        &'static (dyn Fn(usize, usize) + Sync),
    >(body)
}

fn note_serial() {
    SERIAL_JOBS.fetch_add(1, Ordering::Relaxed);
    let c = current_ctx();
    if !c.is_null() {
        unsafe { &*c }.serial.fetch_add(1, Ordering::Relaxed);
    }
}

/// Core scheduler: run `body(start, end)` over disjoint chunks of
/// `0..len`. Inline when small/single-threaded, else pool (or the legacy
/// spawn baseline in spawn mode).
fn run(len: usize, grain: usize, body: &(dyn Fn(usize, usize) + Sync)) {
    if len == 0 {
        return;
    }
    let nt = num_threads();
    let chunk = effective_grain(grain);
    if nt <= 1 || len <= chunk {
        note_serial();
        body(0, len);
        return;
    }
    match dispatch_mode() {
        DispatchMode::Spawn => run_spawn(len, grain.max(1), nt, body),
        DispatchMode::Pool => run_pooled(len, chunk, nt, body),
    }
}

/// Legacy scheduler (the measured baseline): static equal split, one
/// fresh scoped thread per chunk, caller idle at the join.
fn run_spawn(len: usize, grain: usize, nt: usize, body: &(dyn Fn(usize, usize) + Sync)) {
    let t0 = Instant::now();
    // SAFETY: an installed ctx outlives this call (its guard sits on the
    // caller's frame), and ParallelCtx is Sync — safe to share with the
    // scoped threads so spawn-mode profiles carry real cpu/chunk numbers
    let ctx = unsafe { current_ctx().as_ref() };
    let chunks = nt.min(len.div_ceil(grain));
    let chunk = len.div_ceil(chunks);
    std::thread::scope(|s| {
        for t in 0..chunks {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            SPAWNS.fetch_add(1, Ordering::Relaxed);
            s.spawn(move || {
                let tt = Instant::now();
                body(start, end);
                if let Some(c) = ctx {
                    c.chunks[t.min(MAX_WORKERS - 1)].fetch_add(1, Ordering::Relaxed);
                    c.cpu_ns
                        .fetch_add(tt.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            });
        }
    });
    JOBS.fetch_add(1, Ordering::Relaxed);
    if let Some(c) = ctx {
        c.jobs.fetch_add(1, Ordering::Relaxed);
        c.wall_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

fn run_pooled(len: usize, chunk: usize, nt: usize, body: &(dyn Fn(usize, usize) + Sync)) {
    let pool = pool();
    pool.ensure_workers(nt - 1);
    let guard = match pool.submit.try_lock() {
        Ok(g) => g,
        Err(_) => {
            // another forward owns the pool: degrade to inline rather
            // than queueing behind it — progress over parallelism
            BUSY_JOBS.fetch_add(1, Ordering::Relaxed);
            note_serial();
            body(0, len);
            return;
        }
    };
    let spawned = pool.workers_alive.load(Ordering::Acquire);
    let workers = nt.min(spawned + 1).min(len.div_ceil(chunk));
    if workers <= 1 {
        drop(guard);
        note_serial();
        body(0, len);
        return;
    }
    let t0 = Instant::now();
    let ctx = current_ctx();
    let cursor = AtomicUsize::new(0);
    let pending = AtomicUsize::new(workers - 1);
    let panicked = AtomicBool::new(false);
    let job = JobRef {
        // SAFETY: joined below before this frame unwinds or returns
        body: unsafe { erase(body) },
        cursor: &cursor as *const AtomicUsize,
        pending: &pending as *const AtomicUsize,
        panicked: &panicked as *const AtomicBool,
        ctx,
        len,
        chunk,
        workers,
    };
    {
        let mut slot = pool.job_m.lock().unwrap();
        *slot = Some(job);
        pool.epoch.fetch_add(1, Ordering::Release);
        pool.work_cv.notify_all();
    }
    // participate as slot 0 (panic deferred: workers hold pointers into
    // this frame, so the join must happen before any unwind)
    let mine = catch_unwind(AssertUnwindSafe(|| {
        let t = Instant::now();
        claim_chunks(&cursor, len, chunk, 0, ctx, body);
        if !ctx.is_null() {
            unsafe { &*ctx }
                .cpu_ns
                .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }));
    // join: spin briefly (stragglers land within ~µs), then park
    let mut spins = 0u32;
    while pending.load(Ordering::Acquire) != 0 {
        spins += 1;
        if spins >= JOIN_SPIN {
            let mut g = pool.done_m.lock().unwrap();
            while pending.load(Ordering::Acquire) != 0 {
                g = pool.done_cv.wait(g).unwrap();
            }
            break;
        }
        std::hint::spin_loop();
    }
    {
        let mut slot = pool.job_m.lock().unwrap();
        *slot = None;
    }
    drop(guard);
    JOBS.fetch_add(1, Ordering::Relaxed);
    if !ctx.is_null() {
        let c = unsafe { &*ctx };
        c.jobs.fetch_add(1, Ordering::Relaxed);
        c.wall_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
    if let Err(p) = mine {
        resume_unwind(p);
    }
    if panicked.load(Ordering::Acquire) {
        panic!("parallel job body panicked on a pool worker");
    }
}

// ---------------------------------------------------------------------
// public iteration shapes (signatures unchanged from the spawn era)
// ---------------------------------------------------------------------

/// Run `body(start, end)` over disjoint chunks of `0..len` on up to
/// `num_threads()` scheduler slots. `grain` is the target chunk size —
/// if `len` is at or below the (mode-adjusted) grain, the body runs
/// inline on the calling thread.
///
/// The closure only gets `&self`-style shared access, so writes must go
/// through disjoint `&mut` borrows obtained by the caller (see
/// [`parallel_for_mut_chunks`]) or interior mutability.
pub fn parallel_for_chunks<F>(len: usize, grain: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    run(len, grain, &body);
}

#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: used only to hand disjoint row ranges of one &mut borrow to
// the scheduler (see parallel_for_mut_chunks).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Split `data` (viewed as `len` rows of `stride` elements) into disjoint
/// mutable row-chunks and run `body(row_start, rows_chunk)` in parallel.
pub fn parallel_for_mut_chunks<T, F>(data: &mut [T], stride: usize, grain_rows: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(stride > 0, "stride must be positive");
    let rows = data.len() / stride;
    // hard assert: the scheduler only exposes rows × stride elements, so
    // a ragged tail would be silently unprocessed rather than handed to
    // the last chunk as the old splitter did — fail loudly instead
    assert_eq!(data.len(), rows * stride, "data must be rows × stride");
    if rows == 0 {
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    run(rows, grain_rows, &move |r0: usize, r1: usize| {
        // SAFETY: the scheduler hands out disjoint [r0, r1) row ranges,
        // and the caller's &mut borrow keeps the storage alive and
        // exclusive until run() returns.
        let slice =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * stride), (r1 - r0) * stride) };
        body(r0, slice);
    });
}

/// Dynamic per-index scheduler: slots grab the next index until
/// exhausted. For irregular per-item cost.
pub fn parallel_for_dynamic<F>(len: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    run(len, 1, &|start: usize, end: usize| {
        for i in start..end {
            body(i);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly() {
        let sum = AtomicU64::new(0);
        parallel_for_chunks(10_000, 64, |a, b| {
            let mut local = 0u64;
            for i in a..b {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn mut_chunks_write_disjoint_rows() {
        let mut data = vec![0u32; 128 * 16];
        parallel_for_mut_chunks(&mut data, 16, 4, |start_row, chunk| {
            for (r, row) in chunk.chunks_mut(16).enumerate() {
                for v in row.iter_mut() {
                    *v = (start_row + r) as u32;
                }
            }
        });
        for (r, row) in data.chunks(16).enumerate() {
            assert!(row.iter().all(|&v| v == r as u32), "row {r}");
        }
    }

    #[test]
    fn dynamic_visits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_ranges_are_noops() {
        parallel_for_chunks(0, 1, |_, _| panic!("should not run"));
        parallel_for_dynamic(0, |_| panic!("should not run"));
        let mut empty: Vec<u8> = vec![];
        parallel_for_mut_chunks(&mut empty, 4, 1, |_, _| panic!("should not run"));
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
        assert!(num_threads() <= MAX_WORKERS);
    }

    #[test]
    fn max_workers_matches_participation_bounds() {
        assert_eq!(max_workers_for(0, 16), 0);
        assert!(max_workers_for(1, 16) == 1);
        // never more workers than threads, never more than chunks
        let nt = num_threads();
        assert!(max_workers_for(1 << 20, 1) <= nt);
        assert!(max_workers_for(usize::MAX / 2, usize::MAX / 2) <= nt);
    }

    #[test]
    fn panicking_body_propagates_and_pool_survives() {
        let r = std::panic::catch_unwind(|| {
            parallel_for_chunks(4096, 1, |a, _| {
                if a == 0 {
                    panic!("injected");
                }
            });
        });
        assert!(r.is_err(), "panic must reach the caller");
        // the scheduler still works afterwards
        let sum = AtomicU64::new(0);
        parallel_for_chunks(1000, 1, |a, b| {
            sum.fetch_add((b - a) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn ctx_records_jobs_and_chunks() {
        let ctx = ParallelCtx::new();
        {
            let _g = ctx.enter();
            parallel_for_chunks(1 << 14, 8, |_, _| {});
            parallel_for_chunks(4, 1 << 20, |_, _| {}); // below grain: serial
        }
        let snap = ctx.snapshot();
        // the below-grain call is always serial; the first call is a pool
        // job unless single-threaded or the pool was busy with a
        // concurrently-running test's job (then it degrades to serial)
        assert_eq!(snap.jobs + snap.serial, 2, "{snap:?}");
        assert!(snap.serial >= 1, "{snap:?}");
        if snap.jobs == 1 {
            assert!(snap.total_chunks() >= 1, "{snap:?}");
        }
        // a call outside the guard is not attributed
        parallel_for_chunks(1 << 14, 8, |_, _| {});
        assert_eq!(ctx.snapshot().jobs, snap.jobs);
        ctx.reset();
        assert_eq!(ctx.snapshot(), ParSnapshot::default());
    }

    #[test]
    fn results_identical_across_dispatch_modes() {
        // dynamic claiming must not change what gets computed
        let prior = dispatch_mode();
        let run_with = |mode: DispatchMode| {
            set_dispatch_mode_for_bench(mode);
            let mut out = vec![0u64; 4096];
            parallel_for_mut_chunks(&mut out, 1, 7, |r0, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = ((r0 + i) as u64).wrapping_mul(2654435761);
                }
            });
            out
        };
        let a = run_with(DispatchMode::Pool);
        let b = run_with(DispatchMode::Spawn);
        set_dispatch_mode_for_bench(prior);
        assert_eq!(a, b);
    }
}
