//! Data-parallel helpers built on `std::thread::scope`.
//!
//! The offline build has no rayon, so the compute kernels use these
//! primitives instead. `parallel_for_chunks` splits an index range into
//! contiguous chunks, one per worker, and runs the body on scoped threads;
//! for small ranges it degrades to the calling thread (thread spawn is
//! ~10 us, irrelevant for the GEMM-sized work we parallelize but worth
//! avoiding for tiny layers).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for compute. Respects
/// `ESPRESSO_THREADS` if set, else `available_parallelism`.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("ESPRESSO_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Run `body(start, end)` over disjoint chunks of `0..len` on up to
/// `num_threads()` scoped threads. `grain` is the minimum chunk size —
/// if `len <= grain`, the body runs inline on the calling thread.
///
/// The closure only gets `&self`-style shared access, so writes must go
/// through disjoint `&mut` borrows obtained by the caller (see
/// `parallel_for_mut_chunks`) or interior mutability.
pub fn parallel_for_chunks<F>(len: usize, grain: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nt = num_threads();
    if len == 0 {
        return;
    }
    if nt <= 1 || len <= grain {
        body(0, len);
        return;
    }
    let chunks = nt.min(len.div_ceil(grain.max(1)));
    let chunk = len.div_ceil(chunks);
    std::thread::scope(|s| {
        for t in 0..chunks {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let body = &body;
            s.spawn(move || body(start, end));
        }
    });
}

/// Split `data` (viewed as `len` rows of `stride` elements) into disjoint
/// mutable row-chunks and run `body(row_start, rows_chunk)` in parallel.
pub fn parallel_for_mut_chunks<T, F>(data: &mut [T], stride: usize, grain_rows: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(stride > 0, "stride must be positive");
    let rows = data.len() / stride;
    debug_assert_eq!(data.len(), rows * stride);
    let nt = num_threads();
    if rows == 0 {
        return;
    }
    if nt <= 1 || rows <= grain_rows {
        body(0, data);
        return;
    }
    let chunks = nt.min(rows.div_ceil(grain_rows.max(1)));
    let rows_per = rows.div_ceil(chunks);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut row = 0usize;
        let body = &body;
        while !rest.is_empty() {
            let take = (rows_per * stride).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let start_row = row;
            row += take / stride;
            s.spawn(move || body(start_row, head));
        }
    });
}

/// Simple atomic work-stealing-ish dynamic scheduler: workers grab the
/// next index until exhausted. For irregular per-item cost.
pub fn parallel_for_dynamic<F>(len: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let nt = num_threads().min(len.max(1));
    if len == 0 {
        return;
    }
    if nt <= 1 {
        for i in 0..len {
            body(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..nt {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= len {
                    break;
                }
                body(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly() {
        let sum = AtomicU64::new(0);
        parallel_for_chunks(10_000, 64, |a, b| {
            let mut local = 0u64;
            for i in a..b {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn mut_chunks_write_disjoint_rows() {
        let mut data = vec![0u32; 128 * 16];
        parallel_for_mut_chunks(&mut data, 16, 4, |start_row, chunk| {
            for (r, row) in chunk.chunks_mut(16).enumerate() {
                for v in row.iter_mut() {
                    *v = (start_row + r) as u32;
                }
            }
        });
        for (r, row) in data.chunks(16).enumerate() {
            assert!(row.iter().all(|&v| v == r as u32), "row {r}");
        }
    }

    #[test]
    fn dynamic_visits_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_ranges_are_noops() {
        parallel_for_chunks(0, 1, |_, _| panic!("should not run"));
        parallel_for_dynamic(0, |_| panic!("should not run"));
        let mut empty: Vec<u8> = vec![];
        parallel_for_mut_chunks(&mut empty, 4, 1, |_, _| panic!("should not run"));
    }

    #[test]
    fn num_threads_positive() {
        assert!(num_threads() >= 1);
    }
}
