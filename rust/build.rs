//! Build probe: the AVX-512 intrinsics this crate's `bitpack/simd.rs`
//! uses (`_mm512_popcnt_epi64` + friends) were stabilized in Rust 1.89.
//! Older stable toolchains must still build the crate, so the AVX-512
//! kernels are gated behind a custom `espresso_avx512` cfg that this
//! script emits only when the compiling rustc is new enough. Runtime
//! dispatch (`ESPRESSO_SIMD` / CPUID) is layered on top as usual.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (..." — also tolerate "-nightly"/"-beta" suffixes
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(&['.', '-'][..]);
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    if major != 1 {
        // a hypothetical 2.x is newer than anything we gate on
        return Some(if major > 1 { u32::MAX } else { 0 });
    }
    Some(minor)
}

fn main() {
    let minor = rustc_minor().unwrap_or(0);
    if minor >= 80 {
        // check-cfg itself only exists on 1.80+; older cargos would
        // reject the directive
        println!("cargo:rustc-check-cfg=cfg(espresso_avx512)");
    }
    let arch = std::env::var("CARGO_CFG_TARGET_ARCH").unwrap_or_default();
    if arch == "x86_64" && minor >= 89 {
        println!("cargo:rustc-cfg=espresso_avx512");
    }
}
