//! The plan-equivalence property suite — the contract that locks in the
//! compiled forward engine: for ANY architecture, ANY batch size, ANY
//! word width and ANY per-layer backend placement, executing the
//! ahead-of-time [`ForwardPlan`] must be **bit-identical** to the legacy
//! layer-walk (`Network::forward_layerwalk`, the pre-plan semantics kept
//! as the oracle).
//!
//! This holds exactly because the plan does not change any kernel: it
//! resolves representations, backends and scratch ahead of time and then
//! calls the same layer forwards in the same order. Any plan-builder bug
//! (wrong resolved kind, wrong backend routing, broken first-step borrow)
//! breaks bit-identity immediately — and the executor's debug assertions
//! name the offending step.
//!
//! The suite also locks in the allocator contract: after
//! `Network::reserve(batch)`, steady-state forwards perform **zero pool
//! misses** (the paper's "no malloc on the hot path" discipline, §3).

use espresso::format::sample;
use espresso::layers::{Act, Backend};
use espresso::net::Network;
use espresso::tensor::Tensor;
use espresso::util::prop::check_simple;
use espresso::util::rng::Rng;

fn random_images(rng: &mut Rng, spec: &espresso::format::ModelSpec, n: usize) -> Vec<Tensor<u8>> {
    (0..n)
        .map(|_| {
            Tensor::from_vec(
                spec.input_shape,
                (0..spec.input_shape.len())
                    .map(|_| rng.next_u32() as u8)
                    .collect(),
            )
        })
        .collect()
}

/// The legacy layer-walk on a cloned input — exactly what `predict_bytes`
/// did before the plan executor existed.
fn layerwalk_scores<W: espresso::bitpack::Word>(net: &Network<W>, img: &Tensor<u8>) -> Vec<f32> {
    net.forward_layerwalk(Act::Bytes(img.clone()))
        .into_float()
        .data
}

/// Core property: plan-executed forward == legacy layer-walk, bit for
/// bit, on random specs under both uniform backends, single and batched.
#[test]
fn prop_plan_equals_layerwalk_uniform_backends() {
    check_simple(
        "plan-equals-layerwalk",
        24,
        221,
        |r| (r.next_u64(), 1 + r.below(4)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            for backend in [Backend::Binary, Backend::Float] {
                let net = Network::<u64>::from_spec(&spec, backend).unwrap();
                // single-image: borrowed first step vs owned layer-walk
                for img in &imgs {
                    if net.predict_bytes(img) != layerwalk_scores(&net, img) {
                        return false;
                    }
                }
                // batched: plan executes the stacked forward
                let batched = net.predict_batch_bytes(&refs);
                for (img, got) in imgs.iter().zip(&batched) {
                    if *got != layerwalk_scores(&net, img) {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Mixed hybrid placements: random per-layer Float/Binary assignments
/// must produce identical results through the plan and the layer-walk.
#[test]
fn prop_plan_equals_layerwalk_hybrid_placements() {
    check_simple(
        "plan-equals-layerwalk-hybrid",
        20,
        222,
        |r| (r.next_u64(), 2 + r.below(3)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let mut net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let placement: Vec<Backend> = (0..net.layer_count())
                .map(|_| {
                    if rng.bernoulli(0.5) {
                        Backend::Binary
                    } else {
                        Backend::Float
                    }
                })
                .collect();
            net.set_backends(&placement);
            for img in &imgs {
                if net.predict_bytes(img) != layerwalk_scores(&net, img) {
                    return false;
                }
            }
            let batched = net.predict_batch_bytes(&refs);
            imgs.iter()
                .zip(&batched)
                .all(|(img, got)| *got == layerwalk_scores(&net, img))
        },
    );
}

/// u32 packing must satisfy the same equivalence (the A4 width
/// comparison measures identical code paths through the plan).
#[test]
fn prop_plan_equals_layerwalk_u32_words() {
    check_simple(
        "plan-equals-layerwalk-u32",
        12,
        223,
        |r| (r.next_u64(), 1 + r.below(3)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let net = Network::<u32>::from_spec(&spec, Backend::Binary).unwrap();
            for img in &imgs {
                if net.predict_bytes(img) != layerwalk_scores(&net, img) {
                    return false;
                }
            }
            let batched = net.predict_batch_bytes(&refs);
            imgs.iter()
                .zip(&batched)
                .all(|(img, got)| *got == layerwalk_scores(&net, img))
        },
    );
}

/// Auto-placed (cost-model hybrid) plans must also match the layer-walk
/// under the placement they picked.
#[test]
fn prop_auto_placed_plan_equals_layerwalk() {
    check_simple(
        "auto-placement-equals-layerwalk",
        12,
        224,
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, 2);
            let mut net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let placed = net.auto_place().to_vec();
            if placed.len() != net.layer_count() {
                return false;
            }
            imgs.iter()
                .all(|img| net.predict_bytes(img) == layerwalk_scores(&net, img))
        },
    );
}

/// Steady-state no-allocation: once `reserve(batch)` has pre-sized the
/// pools, forwards never miss; and even without an explicit reserve, the
/// second same-shape forward draws everything from the freelists.
#[test]
fn prop_reserved_forwards_never_miss_the_pool() {
    check_simple(
        "reserved-forwards-no-misses",
        16,
        225,
        |r| (r.next_u64(), 1 + r.below(4)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            net.reserve(batch);
            let before = net.ws.stats_total();
            let _ = net.predict_batch_bytes(&refs);
            let _ = net.predict_batch_bytes(&refs);
            let after = net.ws.stats_total();
            // every acquire across both forwards was a freelist hit
            after.misses == before.misses && after.hits > before.hits
        },
    );
}

/// Unreserved batch sizes self-heal: the first forward may miss, the
/// second must not (buffers return to the freelists between forwards).
#[test]
fn steady_state_is_allocation_free_without_explicit_reserve() {
    let mut rng = Rng::new(226);
    let spec = espresso::net::mnist_cnn_spec(&mut rng, 0.5);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let imgs = random_images(&mut rng, &spec, 6);
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    // batch 6 was never reserved: warm up once
    let _ = net.predict_batch_bytes(&refs);
    let warm = net.ws.stats_total();
    for _ in 0..3 {
        let _ = net.predict_batch_bytes(&refs);
    }
    let after = net.ws.stats_total();
    assert_eq!(
        after.misses, warm.misses,
        "steady-state forwards allocated: {warm:?} -> {after:?}"
    );
    assert!(after.hits > warm.hits);
}

/// The paper's evaluation CNN (scaled) through the plan at B=1 and B=16:
/// plan output matches the oracle and the profile records every step.
#[test]
fn bcnn_plan_matches_layerwalk_and_profiles() {
    let mut rng = Rng::new(227);
    let spec = espresso::net::bcnn_spec(&mut rng, 0.125);
    let imgs = random_images(&mut rng, &spec, 16);
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    net.reserve(16);
    assert_eq!(net.predict_bytes(&imgs[0]), layerwalk_scores(&net, &imgs[0]));
    let batched = net.predict_batch_bytes(&refs);
    for (i, (img, got)) in imgs.iter().zip(&batched).enumerate() {
        assert_eq!(*got, layerwalk_scores(&net, img), "image {i}");
    }
    let prof = net.profile();
    assert_eq!(prof.rows.len(), net.layer_count());
    assert!(prof.total_ns() > 0);
    assert!(prof.render().contains("TOTAL"));
}

/// Autotuned plans keep both contracts: bit-identity with the layer-walk
/// (every micro-kernel shape computes the same exact integers) and zero
/// steady-state pool misses — the reservation taken after tuning must
/// agree with the tuned tile/grain choices the forwards actually use.
#[test]
fn tuned_plan_matches_layerwalk_and_stays_allocation_free() {
    let mut rng = Rng::new(228);
    let spec = espresso::net::mnist_cnn_spec(&mut rng, 0.5);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    net.tune();
    assert!(
        net.plan().steps.iter().any(|s| s.kernel.get().is_some()),
        "tune() recorded no kernel choice in the plan"
    );
    let imgs = random_images(&mut rng, &spec, 4);
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    for img in &imgs {
        assert_eq!(net.predict_bytes(img), layerwalk_scores(&net, img));
    }
    let batched = net.predict_batch_bytes(&refs);
    for (img, got) in imgs.iter().zip(&batched) {
        assert_eq!(*got, layerwalk_scores(&net, img));
    }
    // strict no-miss: reserve sizes scratch through the same registry the
    // forwards consult, so no warmup forward is allowed to paper over a
    // reservation/executor disagreement
    net.reserve(4);
    let before = net.ws.stats_total();
    let _ = net.predict_batch_bytes(&refs);
    let _ = net.predict_batch_bytes(&refs);
    let after = net.ws.stats_total();
    assert_eq!(
        after.misses, before.misses,
        "tuned forwards missed the pool: {before:?} -> {after:?}"
    );
    assert!(after.hits > before.hits);
}
