//! Cross-module integration tests: .esp files produced by the Python
//! exporter flowing through every Rust engine, coordinator serving over
//! TCP, and end-to-end accuracy on the exported test set.

use espresso::baseline::{BaselineEngine, BaselineKind};
use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::data;
use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::{argmax, bmlp_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::tensor::{Shape, Tensor};
use espresso::util::rng::Rng;
use std::path::Path;
use std::sync::Arc;

fn trained() -> Option<(ModelSpec, data::Dataset)> {
    let esp = Path::new("artifacts/bmlp_trained.esp");
    let ds = Path::new("artifacts/testset_mnist.espdata");
    if !esp.exists() || !ds.exists() {
        eprintln!("SKIP: trained artifacts missing (run `make artifacts`)");
        return None;
    }
    Some((
        ModelSpec::load(esp).unwrap(),
        data::load_espdata(ds).unwrap(),
    ))
}

/// Python-trained model must hit high accuracy through all four engines,
/// and all engines must agree on every prediction.
#[test]
fn all_engines_agree_on_trained_model() {
    let Some((spec, ds)) = trained() else { return };
    let engines: Vec<Box<dyn Engine>> = vec![
        Box::new(NativeEngine::new(
            Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
            "opt",
        )),
        Box::new(NativeEngine::new(
            Network::<u64>::from_spec(&spec, Backend::Float).unwrap(),
            "float",
        )),
        Box::new(BaselineEngine::from_spec(&spec, BaselineKind::BinaryNet).unwrap()),
        Box::new(BaselineEngine::from_spec(&spec, BaselineKind::NeonLike).unwrap()),
    ];
    let n = 100.min(ds.len());
    let mut correct = vec![0usize; engines.len()];
    for i in 0..n {
        let preds: Vec<usize> = engines
            .iter()
            .map(|e| argmax(&e.predict(&ds.images[i]).unwrap()))
            .collect();
        for w in preds.windows(2) {
            assert_eq!(w[0], w[1], "engines disagree on sample {i}: {preds:?}");
        }
        for (c, &p) in correct.iter_mut().zip(&preds) {
            if p == ds.labels[i] {
                *c += 1;
            }
        }
    }
    for (e, c) in engines.iter().zip(&correct) {
        assert!(
            *c * 10 >= n * 9,
            "{} accuracy too low: {c}/{n}",
            e.name()
        );
    }
}

#[test]
fn u32_packing_network_agrees_with_u64() {
    let Some((spec, ds)) = trained() else { return };
    let n64 = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let n32 = Network::<u32>::from_spec(&spec, Backend::Binary).unwrap();
    for img in ds.images.iter().take(20) {
        assert_eq!(n64.predict_bytes(img), n32.predict_bytes(img));
    }
}

#[test]
fn coordinator_serves_trained_model_over_tcp() {
    let Some((spec, ds)) = trained() else { return };
    let coord = Arc::new(Coordinator::new(BatchConfig::default()));
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    coord.register("mnist", Arc::new(NativeEngine::new(net, "opt")));
    let server = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    // 4 concurrent closed-loop clients classifying the real test set
    let hits: usize = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let addr = server.addr().to_string();
            let ds = &ds;
            handles.push(s.spawn(move || {
                let mut client = tcp::Client::connect(&addr).unwrap();
                let mut hits = 0usize;
                for i in (t..60).step_by(4) {
                    let scores = client.predict("mnist", &ds.images[i].data).unwrap();
                    if argmax(&scores) == ds.labels[i] {
                        hits += 1;
                    }
                }
                hits
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    assert!(hits >= 54, "tcp accuracy too low: {hits}/60");
    // stats are keyed by the registered model name
    let snap = coord.metrics.snapshot("mnist").unwrap();
    assert_eq!(snap.requests, 60);
}

#[test]
fn batched_predictions_equal_single_on_trained_model() {
    let Some((spec, ds)) = trained() else { return };
    let engine = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
        "opt",
    );
    let imgs: Vec<&Tensor<u8>> = ds.images.iter().take(16).collect();
    let batched = engine.predict_batch(&imgs);
    for (img, b) in imgs.iter().zip(batched) {
        assert_eq!(engine.predict(img).unwrap(), b.unwrap());
    }
}

/// esp round trip through Rust writer/reader: save the spec back out and
/// confirm the reloaded network behaves identically.
#[test]
fn esp_rewrite_preserves_behaviour() {
    let Some((spec, ds)) = trained() else { return };
    let tmp = std::env::temp_dir().join("espresso_rewrite.esp");
    spec.save(&tmp).unwrap();
    let spec2 = ModelSpec::load(&tmp).unwrap();
    assert_eq!(spec, spec2);
    let a = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let b = Network::<u64>::from_spec(&spec2, Backend::Binary).unwrap();
    for img in ds.images.iter().take(10) {
        assert_eq!(a.predict_bytes(img), b.predict_bytes(img));
    }
    let _ = std::fs::remove_file(&tmp);
}

/// Hybrid (mixed-backend) networks: every combination of per-layer
/// backends must give the same predictions.
#[test]
fn hybrid_backend_combinations_agree() {
    let mut rng = Rng::new(201);
    let spec = bmlp_spec(&mut rng, 96, 2);
    let mut net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
    let t = Tensor::from_vec(Shape::vector(784), img);
    let reference = net.predict_bytes(&t);
    let n_layers = net.layer_count();
    for mask in 0..(1u32 << n_layers) {
        let backends: Vec<Backend> = (0..n_layers)
            .map(|i| {
                if mask & (1 << i) != 0 {
                    Backend::Float
                } else {
                    Backend::Binary
                }
            })
            .collect();
        net.set_backends(&backends);
        let scores = net.predict_bytes(&t);
        for (a, b) in reference.iter().zip(&scores) {
            assert!(
                (a - b).abs() < 1e-2,
                "mask {mask:b}: {a} vs {b} ({backends:?})"
            );
        }
    }
}

/// Memory claims on the trained model (scaled-down M1 analogue).
#[test]
fn memory_report_saving_is_near_32x() {
    let Some((spec, _)) = trained() else { return };
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let rep = net.memory_report();
    assert!(rep.saving() > 20.0, "saving {}", rep.saving());
}
