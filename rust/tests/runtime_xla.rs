//! Integration tests for the PJRT runtime path: AOT artifacts produced by
//! `make artifacts` (python/compile/aot.py) loaded and executed from Rust.
//!
//! Tests skip (with a notice) when artifacts are missing so `cargo test`
//! works standalone; `make test` always builds artifacts first.

use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::Network;
use espresso::runtime::{artifact_exists, Engine, XlaEngine, XlaModelKind};
use espresso::tensor::{Shape, Tensor};
use espresso::util::rng::Rng;
use std::path::{Path, PathBuf};

fn artifact_dir() -> PathBuf {
    // tests run from the crate root
    PathBuf::from("artifacts")
}

fn skip(name: &str) -> bool {
    if !artifact_exists(&artifact_dir(), name) {
        eprintln!("SKIP: artifact {name} missing (run `make artifacts`)");
        return true;
    }
    false
}

#[test]
fn smoke_artifact_executes() {
    if skip("smoke") {
        return;
    }
    // the smoke module is fn(x, y) = (matmul(x, y) + 2,): execute via the
    // raw xla crate to validate the HLO-text bridge end to end
    let client = xla::PjRtClient::cpu().unwrap();
    let proto =
        xla::HloModuleProto::from_text_file("artifacts/smoke.hlo.txt").unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
    let out = exe.execute::<xla::Literal>(&[x, y]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let v = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    assert_eq!(v, vec![5., 5., 9., 9.]);
}

fn trained_spec() -> Option<ModelSpec> {
    let p = Path::new("artifacts/bmlp_trained.esp");
    if !p.exists() {
        eprintln!("SKIP: artifacts/bmlp_trained.esp missing (run `make artifacts`)");
        return None;
    }
    Some(ModelSpec::load(p).unwrap())
}

/// The decisive cross-stack test: the XLA *binary* engine (Pallas
/// XNOR-popcount GEMM lowered to HLO) must agree with the native Rust
/// binary engine on the same trained weights.
#[test]
fn xla_binary_engine_matches_native() {
    if skip("bmlp_binary_small") {
        return;
    }
    let Some(spec) = trained_spec() else { return };
    let xla_engine =
        XlaEngine::load(&artifact_dir(), "bmlp_binary_small", &spec, XlaModelKind::MlpBinary)
            .unwrap();
    let native = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let mut rng = Rng::new(191);
    for _ in 0..10 {
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(Shape::vector(784), img);
        let xla_scores = xla_engine.predict(&t).unwrap();
        let native_scores = native.predict_bytes(&t);
        assert_eq!(xla_scores.len(), 10);
        for (a, b) in xla_scores.iter().zip(&native_scores) {
            assert!((a - b).abs() < 1e-2, "xla {a} vs native {b}");
        }
    }
}

#[test]
fn xla_float_engine_matches_native_float() {
    if skip("bmlp_float_small") {
        return;
    }
    let Some(spec) = trained_spec() else { return };
    let xla_engine =
        XlaEngine::load(&artifact_dir(), "bmlp_float_small", &spec, XlaModelKind::MlpFloat)
            .unwrap();
    let native = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
    let mut rng = Rng::new(192);
    for _ in 0..5 {
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(Shape::vector(784), img);
        let xla_scores = xla_engine.predict(&t).unwrap();
        let native_scores = native.predict_bytes(&t);
        for (a, b) in xla_scores.iter().zip(&native_scores) {
            assert!((a - b).abs() < 1e-2, "xla {a} vs native {b}");
        }
    }
}

#[test]
fn xla_engine_classifies_test_set() {
    if skip("bmlp_binary_small") {
        return;
    }
    let Some(spec) = trained_spec() else { return };
    let data_path = Path::new("artifacts/testset_mnist.espdata");
    if !data_path.exists() {
        eprintln!("SKIP: test set missing");
        return;
    }
    let ds = espresso::data::load_espdata(data_path).unwrap();
    let engine =
        XlaEngine::load(&artifact_dir(), "bmlp_binary_small", &spec, XlaModelKind::MlpBinary)
            .unwrap();
    let n = 50.min(ds.len());
    let mut correct = 0;
    for i in 0..n {
        let scores = engine.predict(&ds.images[i]).unwrap();
        if espresso::net::argmax(&scores) == ds.labels[i] {
            correct += 1;
        }
    }
    // the trained model reaches ~100% on this set; require a strong bar
    assert!(correct * 10 >= n * 9, "accuracy {correct}/{n}");
}

#[test]
fn xla_cnn_engine_matches_native() {
    if skip("bcnn_float_small") {
        return;
    }
    // generate a matching small CNN spec (stage channels 16/32/64, fc 128)
    let mut rng = Rng::new(193);
    let spec = espresso::net::bcnn_spec(&mut rng, 0.125);
    let engine =
        XlaEngine::load(&artifact_dir(), "bcnn_float_small", &spec, XlaModelKind::CnnFloat)
            .unwrap();
    let native = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u32() as u8).collect();
    let t = Tensor::from_vec(Shape::new(32, 32, 3), img);
    let xla_scores = engine.predict(&t).unwrap();
    let native_scores = native.predict_bytes(&t);
    assert_eq!(xla_scores.len(), 10);
    for (a, b) in xla_scores.iter().zip(&native_scores) {
        let denom = b.abs().max(1.0);
        assert!((a - b).abs() / denom < 2e-2, "xla {a} vs native {b}");
    }
}

#[test]
fn wrong_spec_fails_validation() {
    if skip("bmlp_binary_small") {
        return;
    }
    // a spec with the wrong hidden width must be rejected at load time
    let mut rng = Rng::new(194);
    let wrong = espresso::net::bmlp_spec(&mut rng, 128, 2);
    let err = XlaEngine::load(
        &artifact_dir(),
        "bmlp_binary_small",
        &wrong,
        XlaModelKind::MlpBinary,
    );
    assert!(err.is_err(), "mismatched spec should fail meta validation");
}
