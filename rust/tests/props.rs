//! Property-based tests over the packed-kernel invariants, using the
//! in-crate property harness (`util::prop`).

use espresso::bitpack::{
    self, pack_matrix_rows, pack_signs, unpack_signs, words_for, BitPlanes,
};
use espresso::layers::{Act, Backend, ConvLayer, DenseLayer, Layer};
use espresso::tensor::{BitTensor, Shape, Tensor};
use espresso::util::prop::{check, check_simple, shrink_usize};
use espresso::util::rng::Rng;

#[test]
fn prop_pack_unpack_roundtrip() {
    check(
        "pack-unpack-roundtrip",
        200,
        11,
        |r| {
            let n = 1 + r.below(500);
            (n, r.signs(n))
        },
        |(n, v)| {
            shrink_usize(*n, 1)
                .into_iter()
                .map(|m| (m, v[..m].to_vec()))
                .collect()
        },
        |(n, v)| unpack_signs(&pack_signs::<u64>(v), *n) == *v,
    );
}

#[test]
fn prop_dot_symmetry_and_bounds() {
    check_simple(
        "dot-symmetry",
        300,
        12,
        |r| {
            let n = 1 + r.below(400);
            (n, r.signs(n), r.signs(n))
        },
        |(n, a, b)| {
            let pa = pack_signs::<u64>(a);
            let pb = pack_signs::<u64>(b);
            let ab = bitpack::dot(&pa, &pb, *n);
            let ba = bitpack::dot(&pb, &pa, *n);
            // symmetric, bounded, correct parity
            ab == ba && ab.abs() <= *n as i32 && (ab - *n as i32) % 2 == 0
        },
    );
}

#[test]
fn prop_dot_self_is_n() {
    check_simple(
        "dot-self",
        200,
        13,
        |r| {
            let n = 1 + r.below(300);
            (n, r.signs(n))
        },
        |(n, a)| {
            let pa = pack_signs::<u64>(a);
            bitpack::dot(&pa, &pa, *n) == *n as i32
        },
    );
}

#[test]
fn prop_dot_negation_flips_sign() {
    check_simple(
        "dot-negation",
        200,
        14,
        |r| {
            let n = 1 + r.below(300);
            (n, r.signs(n), r.signs(n))
        },
        |(n, a, b)| {
            let neg: Vec<f32> = b.iter().map(|x| -x).collect();
            let pa = pack_signs::<u64>(a);
            let pb = pack_signs::<u64>(b);
            let pn = pack_signs::<u64>(&neg);
            bitpack::dot(&pa, &pb, *n) == -bitpack::dot(&pa, &pn, *n)
        },
    );
}

#[test]
fn prop_gemm_rows_are_gemv() {
    check_simple(
        "gemm-rows-are-gemv",
        40,
        15,
        |r| {
            let m = 1 + r.below(6);
            let n = 1 + r.below(40);
            let k = 1 + r.below(200);
            (m, n, k, r.signs(m * k), r.signs(n * k))
        },
        |(m, n, k, a, b)| {
            let pa = pack_matrix_rows::<u64>(a, *m, *k);
            let pb = pack_matrix_rows::<u64>(b, *n, *k);
            let full = bitpack::gemm::<u64>(&pa, &pb, *m, *n, *k);
            let kw = words_for::<u64>(*k);
            (0..*m).all(|i| {
                let row = bitpack::gemv::<u64>(&pa[i * kw..(i + 1) * kw], &pb, *n, *k);
                row == full[i * *n..(i + 1) * *n]
            })
        },
    );
}

#[test]
fn prop_bitplane_linear_in_input() {
    // bitplane_dot(x, w) + bitplane_dot(y, w) == dot over (x + y) when no
    // overflow: test with x + y <= 255 per element
    check_simple(
        "bitplane-linearity",
        60,
        16,
        |r| {
            let k = 1 + r.below(300);
            let x: Vec<u8> = (0..k).map(|_| (r.next_u32() % 128) as u8).collect();
            let y: Vec<u8> = (0..k).map(|_| (r.next_u32() % 128) as u8).collect();
            (k, x, y, r.signs(k))
        },
        |(k, x, y, w)| {
            let pw = pack_matrix_rows::<u64>(w, 1, *k);
            let dx = bitpack::bitplane_dot(&BitPlanes::<u64>::decompose(x), &pw);
            let dy = bitpack::bitplane_dot(&BitPlanes::<u64>::decompose(y), &pw);
            let sum: Vec<u8> = x.iter().zip(y).map(|(a, b)| a + b).collect();
            let ds = bitpack::bitplane_dot(&BitPlanes::<u64>::decompose(&sum), &pw);
            ds == dx + dy
        },
    );
}

#[test]
fn prop_bit_tensor_flatten_preserves_values() {
    check_simple(
        "flatten-preserves",
        60,
        17,
        |r| {
            let m = 1 + r.below(6);
            let n = 1 + r.below(6);
            let l = 1 + r.below(130);
            let mut d = vec![0f32; m * n * l];
            r.fill_signs(&mut d);
            (Shape::new(m, n, l), d)
        },
        |(s, d)| {
            let t = Tensor::from_vec(*s, d.clone());
            let bt = BitTensor::<u64>::from_tensor(&t);
            let flat = bt.flatten();
            flat.to_tensor().data == t.data
        },
    );
}

/// Dense layer: binary path == float path for random layer shapes/params.
#[test]
fn prop_dense_binary_equals_float() {
    let mut rng = Rng::new(18);
    let ws = espresso::alloc::Workspace::new();
    for _ in 0..25 {
        let k = 8 + rng.below(256);
        let n = 1 + rng.below(128);
        let w = rng.signs(n * k);
        let layer: DenseLayer<u64> = DenseLayer::new(k, n, &w, None, true);
        let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
        let f = layer
            .forward(Act::Float(x.clone()), Backend::Float, &ws)
            .into_float();
        let b = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(f.data, b.data, "k={k} n={n}");
    }
}

/// Conv layer: binary path == float path for random geometries, padding
/// correction included.
#[test]
fn prop_conv_binary_equals_float() {
    let mut rng = Rng::new(19);
    let ws = espresso::alloc::Workspace::new();
    for trial in 0..15 {
        let m = 4 + rng.below(6);
        let n = 4 + rng.below(6);
        let l = 1 + rng.below(80);
        let f = 1 + rng.below(24);
        let k = [1usize, 3, 5][rng.below(3)];
        let pad = rng.below(k / 2 + 1);
        if m + 2 * pad < k || n + 2 * pad < k {
            continue;
        }
        let w = rng.signs(f * k * k * l);
        let mut layer: ConvLayer<u64> =
            ConvLayer::new(l, f, k, k, 1, pad, &w, None, true, None);
        let s = Shape::new(m, n, l);
        layer.prepare(s);
        let mut d = vec![0f32; s.len()];
        rng.fill_signs(&mut d);
        let x = Tensor::from_vec(s, d);
        let ff = layer
            .forward(Act::Float(x.clone()), Backend::Float, &ws)
            .into_float();
        let bb = layer
            .forward(Act::Float(x), Backend::Binary, &ws)
            .into_float();
        assert_eq!(
            ff.data, bb.data,
            "trial {trial}: m={m} n={n} l={l} f={f} k={k} pad={pad}"
        );
    }
}

/// Failure injection: corrupted .esp bytes must error, never panic.
#[test]
fn prop_corrupt_esp_never_panics() {
    let mut rng = Rng::new(20);
    let spec = espresso::net::bmlp_spec(&mut rng, 32, 1);
    let mut buf = Vec::new();
    spec.write_to(&mut buf).unwrap();
    for trial in 0..200 {
        let mut bad = buf.clone();
        match trial % 3 {
            0 => {
                // flip a random byte
                let i = rng.below(bad.len());
                bad[i] ^= 1 << rng.below(8);
            }
            1 => {
                // truncate
                bad.truncate(rng.below(bad.len()));
            }
            _ => {
                // splice garbage
                let i = rng.below(bad.len());
                for b in bad[i..].iter_mut().take(16) {
                    *b = rng.next_u32() as u8;
                }
            }
        }
        // must return (Ok with different weights is fine for byte flips in
        // weight data) — the point is no panic / no unbounded allocation
        let _ = std::panic::catch_unwind(|| {
            let _ = espresso::format::ModelSpec::read_from(&mut bad.as_slice());
        });
    }
}
