//! End-to-end serving load suite over real TCP: the pipelined front end
//! must (a) return bit-identical scores to direct `Engine::predict` under
//! heavy concurrent load, (b) let a SINGLE connection saturate GEMM-level
//! batching via `predict_batch` frames, (c) reject excess load promptly
//! with the distinct `overloaded` status once `queue_depth` is saturated,
//! and (d) survive malformed frames, counting them as protocol errors
//! instead of reporting clean closes.

use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::tensor::{Shape, Tensor};
use espresso::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

const INPUT: usize = 784;

/// Serve a small binary MLP under `cfg`; returns the coordinator, the
/// running server and an identical direct-engine oracle.
fn serve_mlp(cfg: BatchConfig) -> (Arc<Coordinator>, tcp::ServerHandle, NativeEngine) {
    let mut rng = Rng::new(4242);
    let spec = bmlp_spec(&mut rng, 64, 1);
    let served = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let direct = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let coord = Arc::new(Coordinator::new(cfg));
    coord.register("bmlp", Arc::new(NativeEngine::new(served, "opt")));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    (coord, handle, NativeEngine::new(direct, "direct"))
}

fn image(rng: &mut Rng) -> Vec<u8> {
    (0..INPUT).map(|_| rng.next_u32() as u8).collect()
}

fn tensor(img: &[u8]) -> Tensor<u8> {
    Tensor::from_vec(Shape::vector(img.len()), img.to_vec())
}

/// Assemble one raw request frame: `u32 len | u8 op | payload`.
fn frame(op: u8, payload: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(5 + payload.len());
    f.extend_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
    f.push(op);
    f.extend_from_slice(payload);
    f
}

/// Read one response frame: returns (status, payload).
fn read_reply(s: &mut TcpStream) -> std::io::Result<(u8, Vec<u8>)> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf)?;
    assert!(!buf.is_empty(), "server sent an empty frame");
    Ok((buf[0], buf[1..].to_vec()))
}

fn batch_payload(model: &str, imgs: &[&[u8]]) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&(model.len() as u16).to_le_bytes());
    p.extend_from_slice(model.as_bytes());
    p.extend_from_slice(&(imgs.len() as u32).to_le_bytes());
    for img in imgs {
        p.extend_from_slice(&(img.len() as u32).to_le_bytes());
        p.extend_from_slice(img);
    }
    p
}

/// Acceptance bar: 32 concurrent connections × 100 requests each return
/// bit-identical scores to direct `Engine::predict`, none lost.
#[test]
fn serve_32_connections_100_requests_matches_direct() {
    let (coord, handle, direct) = serve_mlp(BatchConfig::default());
    let addr = handle.addr().to_string();
    std::thread::scope(|s| {
        for c in 0..32u64 {
            let addr = addr.clone();
            let direct = &direct;
            s.spawn(move || {
                let mut client = tcp::Client::connect(&addr).unwrap();
                let mut rng = Rng::new(1000 + c);
                for r in 0..100 {
                    let img = image(&mut rng);
                    let scores = client.predict("bmlp", &img).unwrap();
                    let want = direct.predict(&tensor(&img)).unwrap();
                    assert_eq!(scores, want, "conn {c} request {r}");
                }
            });
        }
    });
    let snap = coord.metrics.snapshot("bmlp").unwrap();
    assert_eq!(snap.requests, 32 * 100, "every request accounted for");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.rejected, 0, "default queue depth must not reject");
}

/// Acceptance bar: ONE connection sending `predict_batch` frames drives
/// `mean_batch > 1`, with metrics keyed by the registered model name.
#[test]
fn single_connection_wire_batch_saturates_gemm_batching() {
    let (coord, handle, direct) = serve_mlp(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_depth: 1024,
    });
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    let mut rng = Rng::new(77);
    let imgs: Vec<Vec<u8>> = (0..64).map(|_| image(&mut rng)).collect();
    let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
    let replies = client.predict_batch("bmlp", &refs).unwrap();
    assert_eq!(replies.len(), 64);
    for (img, reply) in imgs.iter().zip(replies) {
        let want = direct.predict(&tensor(img)).unwrap();
        assert_eq!(reply.scores().unwrap(), want);
    }
    let snap = coord.metrics.snapshot("bmlp").unwrap();
    assert_eq!(snap.requests, 64);
    assert!(
        snap.mean_batch > 1.0,
        "a single wire-batch connection must fill GEMM batches, got mean {}",
        snap.mean_batch
    );
    assert!(
        coord.metrics.snapshot("opt").is_none(),
        "metrics must key by registered name, not engine label"
    );
}

/// Engine that serves one request per 600 ms — slow enough that the
/// admission bound saturates deterministically: `queue_depth` counts
/// in-flight requests (queued + executing), so no slot can free before
/// the first service completes at t=600 ms.
struct Slow;

impl Engine for Slow {
    fn name(&self) -> String {
        "slow-engine".into()
    }

    fn input_shape(&self) -> Shape {
        Shape::vector(4)
    }

    fn predict(&self, img: &Tensor<u8>) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(Duration::from_millis(600));
        Ok(vec![img.data[0] as f32])
    }

    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<anyhow::Result<Vec<f32>>> {
        std::thread::sleep(Duration::from_millis(600));
        imgs.iter().map(|i| Ok(vec![i.data[0] as f32])).collect()
    }
}

/// Acceptance bar: with `queue_depth` saturated, excess requests get the
/// `overloaded` status promptly (well within one service time), nothing
/// hangs or is lost, and rejections land in the stats table.
#[test]
fn overload_rejects_promptly_and_is_counted() {
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_depth: 2,
    }));
    coord.register("slow", Arc::new(Slow));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let addr = handle.addr().to_string();

    let img = |v: u8| vec![v, 0, 0, 0];
    // connection A floods without reading replies (pipelined): batch #1
    // admits exactly 2 of 4 (in-flight bound 2, nothing replied yet),
    // batch #2 finds both slots still held (first service ends at
    // t=600 ms) and is rejected in full
    let mut flood = TcpStream::connect(&addr).unwrap();
    let imgs1 = [img(1), img(2), img(3), img(4)];
    let refs1: Vec<&[u8]> = imgs1.iter().map(|i| i.as_slice()).collect();
    flood
        .write_all(&frame(tcp::OP_PREDICT_BATCH, &batch_payload("slow", &refs1)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let imgs2 = [img(5), img(6), img(7), img(8)];
    let refs2: Vec<&[u8]> = imgs2.iter().map(|i| i.as_slice()).collect();
    flood
        .write_all(&frame(tcp::OP_PREDICT_BATCH, &batch_payload("slow", &refs2)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // both in-flight slots are held and the engine is mid-service until
    // t=600 ms: a fresh client's batch must be rejected in full, and the
    // reply must arrive promptly — NOT after the engine drains
    let mut client = tcp::Client::connect(&addr).unwrap();
    let imgs3 = [img(9), img(10), img(11), img(12)];
    let refs3: Vec<&[u8]> = imgs3.iter().map(|i| i.as_slice()).collect();
    let t0 = Instant::now();
    let replies = client.predict_batch("slow", &refs3).unwrap();
    let elapsed = t0.elapsed();
    assert!(
        replies.iter().all(|r| *r == tcp::Reply::Overloaded),
        "saturated queue must reject the whole batch: {replies:?}"
    );
    assert!(
        elapsed < Duration::from_millis(500),
        "overload must be reported promptly (service time is 600 ms), took {elapsed:?}"
    );

    // nothing admitted is lost: connection A eventually receives both
    // reply frames — scores for the admitted prefix, overloaded markers
    // for the rest
    let mut score_entries = 0usize;
    let mut overloaded_entries = 0usize;
    for _ in 0..2 {
        let (status, body) = read_reply(&mut flood).unwrap();
        assert_eq!(status, tcp::STATUS_OK);
        let count = u32::from_le_bytes([body[0], body[1], body[2], body[3]]) as usize;
        assert_eq!(count, 4);
        let mut pos = 4;
        for _ in 0..count {
            let st = body[pos];
            let len =
                u32::from_le_bytes([body[pos + 1], body[pos + 2], body[pos + 3], body[pos + 4]])
                    as usize;
            pos += 5 + len;
            match st {
                tcp::STATUS_OK => score_entries += 1,
                tcp::STATUS_OVERLOADED => overloaded_entries += 1,
                other => panic!("unexpected item status {other}"),
            }
        }
        assert_eq!(pos, body.len());
    }
    assert_eq!(score_entries, 2, "exactly batch #1's admitted pair executes");
    assert_eq!(overloaded_entries, 6);

    let snap = coord.metrics.snapshot("slow").unwrap();
    assert_eq!(snap.requests, 2, "only admitted requests are executed");
    assert_eq!(snap.rejected, 2 + 4 + 4);
    assert!(snap.queue_peak >= 2);
    // rejections are visible in the rendered stats table
    let stats = coord.metrics.render();
    let line = stats
        .lines()
        .find(|l| l.starts_with("slow"))
        .unwrap_or_else(|| panic!("no slow row in:\n{stats}"));
    assert!(
        line.split_whitespace().any(|w| w == "10"),
        "rejection count missing from stats row: {line}"
    );
}

/// Satellite: malformed frames keep the server alive, come back as err
/// frames, and increment the protocol-error counter (the old frame
/// reader reported every one of these as a clean peer close).
#[test]
fn malformed_frames_keep_server_alive_and_are_counted() {
    let (coord, handle, _direct) = serve_mlp(BatchConfig::default());
    let addr = handle.addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();

    // (a) truncated predict payload
    s.write_all(&frame(tcp::OP_PREDICT, &[7u8])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("truncated"),
        "{body:?}"
    );

    // (b) img_len header disagrees with the actual bytes
    let mut p = Vec::new();
    p.extend_from_slice(&4u16.to_le_bytes());
    p.extend_from_slice(b"bmlp");
    p.extend_from_slice(&10u32.to_le_bytes());
    p.extend_from_slice(&[1, 2, 3]);
    s.write_all(&frame(tcp::OP_PREDICT, &p)).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("length mismatch"),
        "{body:?}"
    );

    // (c) model name that is not UTF-8
    let mut p = Vec::new();
    p.extend_from_slice(&2u16.to_le_bytes());
    p.extend_from_slice(&[0xff, 0xfe]);
    p.extend_from_slice(&0u32.to_le_bytes());
    s.write_all(&frame(tcp::OP_PREDICT, &p)).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(String::from_utf8_lossy(&body).contains("utf8"), "{body:?}");

    // (d) unknown op
    s.write_all(&frame(99, &[])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("unknown op"),
        "{body:?}"
    );

    // the connection survived all four: a well-formed request still works
    s.write_all(&frame(tcp::OP_PING, &[])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_OK);
    assert_eq!(body, b"pong");

    // (e) oversize length prefix: err frame, then the connection closes
    let mut s2 = TcpStream::connect(&addr).unwrap();
    s2.write_all(&(((64u32 << 20) + 2).to_le_bytes())).unwrap();
    let (st, body) = read_reply(&mut s2).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("exceeds"),
        "{body:?}"
    );
    let mut probe = [0u8; 1];
    assert_eq!(
        s2.read(&mut probe).unwrap(),
        0,
        "connection must close after an unresyncable framing violation"
    );

    // (f) mid-frame truncation: announce 100 bytes, send 1, hang up
    let mut s3 = TcpStream::connect(&addr).unwrap();
    s3.write_all(&100u32.to_le_bytes()).unwrap();
    s3.write_all(&[tcp::OP_PING]).unwrap();
    drop(s3);

    // all six violations are counted (f lands asynchronously)
    let deadline = Instant::now() + Duration::from_secs(5);
    while coord.metrics.protocol_errors() < 6 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(coord.metrics.protocol_errors(), 6);
    assert!(coord.metrics.render().contains("6 protocol errors"));

    // and the server still accepts fresh connections
    let mut client = tcp::Client::connect(&addr).unwrap();
    client.ping().unwrap();
}

/// Satellite: `shutdown` wakes the blocking acceptor immediately — no
/// 5 ms poll loop, no hang waiting for a next connection.
#[test]
fn shutdown_is_prompt() {
    let (_coord, mut handle, _direct) = serve_mlp(BatchConfig::default());
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    client.ping().unwrap();
    drop(client);
    let t0 = Instant::now();
    handle.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "shutdown took {:?}",
        t0.elapsed()
    );
}
