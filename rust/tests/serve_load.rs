//! End-to-end serving load suite over real TCP against the event-driven
//! front end (the thread-per-connection model is retired; `--io-model
//! threads` only parses as an alias). The suite checks that the front
//! end (a) returns bit-identical scores to direct `Engine::predict`
//! under heavy concurrent load, (b) lets a SINGLE connection saturate
//! GEMM-level batching via `predict_batch` frames, (c) rejects excess
//! load promptly with the distinct `overloaded` status once
//! `queue_depth` is saturated, (d) survives malformed frames, counting
//! them as protocol errors instead of reporting clean closes, (e) parses
//! frames trickled in one byte at a time, (f) keeps pipelined replies in
//! request order across partial writes, (g) keeps the OS thread count
//! bounded by cores + a constant through connection churn at c=256, and
//! (h) answers every frame of a pipelined burst larger than the reply
//! window, across a client half-close. Everything runs under the default
//! `SO_REUSEPORT` per-loop acceptors; registry/hot-swap behaviour has
//! its own suite in `registry_swap.rs`.

use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::tensor::{Shape, Tensor};
use espresso::util::rng::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

const INPUT: usize = 784;

/// Serve a small binary MLP under `cfg`; returns the coordinator, the
/// running server and an identical direct-engine oracle.
fn serve_mlp(cfg: BatchConfig) -> (Arc<Coordinator>, tcp::ServerHandle, NativeEngine) {
    let mut rng = Rng::new(4242);
    let spec = bmlp_spec(&mut rng, 64, 1);
    let served = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let direct = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let coord = Arc::new(Coordinator::new(cfg));
    coord.register("bmlp", Arc::new(NativeEngine::new(served, "opt")));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    (coord, handle, NativeEngine::new(direct, "direct"))
}

fn image(rng: &mut Rng) -> Vec<u8> {
    (0..INPUT).map(|_| rng.next_u32() as u8).collect()
}

fn tensor(img: &[u8]) -> Tensor<u8> {
    Tensor::from_vec(Shape::vector(img.len()), img.to_vec())
}

/// Assemble one raw request frame: `u32 len | u8 op | payload`.
fn frame(op: u8, payload: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(5 + payload.len());
    f.extend_from_slice(&((payload.len() + 1) as u32).to_le_bytes());
    f.push(op);
    f.extend_from_slice(payload);
    f
}

/// Read one response frame: returns (status, payload).
fn read_reply(s: &mut TcpStream) -> std::io::Result<(u8, Vec<u8>)> {
    let mut len = [0u8; 4];
    s.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf)?;
    assert!(!buf.is_empty(), "server sent an empty frame");
    Ok((buf[0], buf[1..].to_vec()))
}

fn predict_payload(model: &str, img: &[u8]) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&(model.len() as u16).to_le_bytes());
    p.extend_from_slice(model.as_bytes());
    p.extend_from_slice(&(img.len() as u32).to_le_bytes());
    p.extend_from_slice(img);
    p
}

fn batch_payload(model: &str, imgs: &[&[u8]]) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&(model.len() as u16).to_le_bytes());
    p.extend_from_slice(model.as_bytes());
    p.extend_from_slice(&(imgs.len() as u32).to_le_bytes());
    for img in imgs {
        p.extend_from_slice(&(img.len() as u32).to_le_bytes());
        p.extend_from_slice(img);
    }
    p
}

/// Decode a wire-batch response body into (status, item) pairs.
fn decode_batch_body(body: &[u8]) -> Vec<(u8, Vec<u8>)> {
    let count = u32::from_le_bytes([body[0], body[1], body[2], body[3]]) as usize;
    let mut items = Vec::with_capacity(count);
    let mut pos = 4;
    for _ in 0..count {
        let st = body[pos];
        let len = u32::from_le_bytes([body[pos + 1], body[pos + 2], body[pos + 3], body[pos + 4]])
            as usize;
        items.push((st, body[pos + 5..pos + 5 + len].to_vec()));
        pos += 5 + len;
    }
    assert_eq!(pos, body.len(), "trailing bytes in batch body");
    items
}

fn decode_scores(item: &[u8]) -> Vec<f32> {
    assert_eq!(item.len() % 4, 0);
    item.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Acceptance bar: 32 concurrent connections × 100 requests each return
/// bit-identical scores to direct `Engine::predict`, none lost.
#[test]
fn serve_32x100_matches_direct() {
    let (coord, handle, direct) = serve_mlp(BatchConfig::default());
    let addr = handle.addr().to_string();
    std::thread::scope(|s| {
        for c in 0..32u64 {
            let addr = addr.clone();
            let direct = &direct;
            s.spawn(move || {
                let mut client = tcp::Client::connect(&addr).unwrap();
                let mut rng = Rng::new(1000 + c);
                for r in 0..100 {
                    let img = image(&mut rng);
                    let scores = client.predict("bmlp", &img).unwrap();
                    let want = direct.predict(&tensor(&img)).unwrap();
                    assert_eq!(scores, want, "conn {c} request {r}");
                }
            });
        }
    });
    let snap = coord.metrics.snapshot("bmlp").unwrap();
    assert_eq!(snap.requests, 32 * 100, "every request accounted for");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.rejected, 0, "default queue depth must not reject");
}

/// Acceptance bar: ONE connection sending `predict_batch` frames drives
/// `mean_batch > 1`, with metrics keyed by the registered model name.
#[test]
fn wire_batch_saturates_gemm_batching() {
    let (coord, handle, direct) = serve_mlp(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_depth: 1024,
        ..BatchConfig::default()
    });
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    let mut rng = Rng::new(77);
    let imgs: Vec<Vec<u8>> = (0..64).map(|_| image(&mut rng)).collect();
    let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
    let replies = client.predict_batch("bmlp", &refs).unwrap();
    assert_eq!(replies.len(), 64);
    for (img, reply) in imgs.iter().zip(replies) {
        let want = direct.predict(&tensor(img)).unwrap();
        assert_eq!(reply.scores().unwrap(), want);
    }
    let snap = coord.metrics.snapshot("bmlp").unwrap();
    assert_eq!(snap.requests, 64);
    assert!(
        snap.mean_batch > 1.0,
        "a single wire-batch connection must fill GEMM batches, got mean {}",
        snap.mean_batch
    );
    assert!(
        coord.metrics.snapshot("opt").is_none(),
        "metrics must key by registered name, not engine label"
    );
}

/// Engine that serves one request per 600 ms — slow enough that the
/// admission bound saturates deterministically: `queue_depth` counts
/// in-flight requests (queued + executing), so no slot can free before
/// the first service completes at t=600 ms.
struct Slow;

impl Engine for Slow {
    fn name(&self) -> String {
        "slow-engine".into()
    }

    fn input_shape(&self) -> Shape {
        Shape::vector(4)
    }

    fn predict(&self, img: &Tensor<u8>) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(Duration::from_millis(600));
        Ok(vec![img.data[0] as f32])
    }

    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<anyhow::Result<Vec<f32>>> {
        std::thread::sleep(Duration::from_millis(600));
        imgs.iter().map(|i| Ok(vec![i.data[0] as f32])).collect()
    }
}

/// Acceptance bar: with `queue_depth` saturated, excess requests get the
/// `overloaded` status promptly (well within one service time), nothing
/// hangs or is lost, and rejections land in the stats table.
#[test]
fn overload_rejects_promptly() {
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch: 1,
        max_wait: Duration::from_millis(1),
        queue_depth: 2,
        ..BatchConfig::default()
    }));
    coord.register("slow", Arc::new(Slow));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let addr = handle.addr().to_string();

    let img = |v: u8| vec![v, 0, 0, 0];
    // connection A floods without reading replies (pipelined): batch #1
    // admits exactly 2 of 4 (in-flight bound 2, nothing replied yet),
    // batch #2 finds both slots still held (first service ends at
    // t=600 ms) and is rejected in full
    let mut flood = TcpStream::connect(&addr).unwrap();
    let imgs1 = [img(1), img(2), img(3), img(4)];
    let refs1: Vec<&[u8]> = imgs1.iter().map(|i| i.as_slice()).collect();
    flood
        .write_all(&frame(tcp::OP_PREDICT_BATCH, &batch_payload("slow", &refs1)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(100));
    let imgs2 = [img(5), img(6), img(7), img(8)];
    let refs2: Vec<&[u8]> = imgs2.iter().map(|i| i.as_slice()).collect();
    flood
        .write_all(&frame(tcp::OP_PREDICT_BATCH, &batch_payload("slow", &refs2)))
        .unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // both in-flight slots are held and the engine is mid-service until
    // t=600 ms: a fresh client's batch must be rejected in full, and the
    // reply must arrive promptly — NOT after the engine drains
    let mut client = tcp::Client::connect(&addr).unwrap();
    let imgs3 = [img(9), img(10), img(11), img(12)];
    let refs3: Vec<&[u8]> = imgs3.iter().map(|i| i.as_slice()).collect();
    let t0 = Instant::now();
    let replies = client.predict_batch("slow", &refs3).unwrap();
    let elapsed = t0.elapsed();
    assert!(
        replies.iter().all(|r| *r == tcp::Reply::Overloaded),
        "saturated queue must reject the whole batch: {replies:?}"
    );
    assert!(
        elapsed < Duration::from_millis(500),
        "overload must be reported promptly (service time is 600 ms), took {elapsed:?}"
    );

    // nothing admitted is lost: connection A eventually receives both
    // reply frames — scores for the admitted prefix, overloaded markers
    // for the rest
    let mut score_entries = 0usize;
    let mut overloaded_entries = 0usize;
    for _ in 0..2 {
        let (status, body) = read_reply(&mut flood).unwrap();
        assert_eq!(status, tcp::STATUS_OK);
        let items = decode_batch_body(&body);
        assert_eq!(items.len(), 4);
        for (st, _) in items {
            match st {
                tcp::STATUS_OK => score_entries += 1,
                tcp::STATUS_OVERLOADED => overloaded_entries += 1,
                other => panic!("unexpected item status {other}"),
            }
        }
    }
    assert_eq!(score_entries, 2, "exactly batch #1's admitted pair executes");
    assert_eq!(overloaded_entries, 6);

    let snap = coord.metrics.snapshot("slow").unwrap();
    assert_eq!(snap.requests, 2, "only admitted requests are executed");
    assert_eq!(snap.rejected, 2 + 4 + 4);
    assert!(snap.queue_peak >= 2);
    // rejections are visible in the rendered stats table
    let stats = coord.metrics.render();
    let line = stats
        .lines()
        .find(|l| l.starts_with("slow"))
        .unwrap_or_else(|| panic!("no slow row in:\n{stats}"));
    assert!(
        line.split_whitespace().any(|w| w == "10"),
        "rejection count missing from stats row: {line}"
    );
}

/// Satellite: malformed frames keep the server alive, come back as err
/// frames, and increment the protocol-error counter (the old frame
/// reader reported every one of these as a clean peer close).
#[test]
fn malformed_frames_counted() {
    let (coord, handle, _direct) = serve_mlp(BatchConfig::default());
    let addr = handle.addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();

    // (a) truncated predict payload
    s.write_all(&frame(tcp::OP_PREDICT, &[7u8])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("truncated"),
        "{body:?}"
    );

    // (b) img_len header disagrees with the actual bytes
    let mut p = Vec::new();
    p.extend_from_slice(&4u16.to_le_bytes());
    p.extend_from_slice(b"bmlp");
    p.extend_from_slice(&10u32.to_le_bytes());
    p.extend_from_slice(&[1, 2, 3]);
    s.write_all(&frame(tcp::OP_PREDICT, &p)).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("length mismatch"),
        "{body:?}"
    );

    // (c) model name that is not UTF-8
    let mut p = Vec::new();
    p.extend_from_slice(&2u16.to_le_bytes());
    p.extend_from_slice(&[0xff, 0xfe]);
    p.extend_from_slice(&0u32.to_le_bytes());
    s.write_all(&frame(tcp::OP_PREDICT, &p)).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(String::from_utf8_lossy(&body).contains("utf8"), "{body:?}");

    // (d) unknown op
    s.write_all(&frame(99, &[])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("unknown op"),
        "{body:?}"
    );

    // the connection survived all four: a well-formed request still works
    s.write_all(&frame(tcp::OP_PING, &[])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_OK);
    assert_eq!(body, b"pong");

    // (e) oversize length prefix: err frame, then the connection closes
    let mut s2 = TcpStream::connect(&addr).unwrap();
    s2.write_all(&(((64u32 << 20) + 2).to_le_bytes())).unwrap();
    let (st, body) = read_reply(&mut s2).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("exceeds"),
        "{body:?}"
    );
    let mut probe = [0u8; 1];
    assert_eq!(
        s2.read(&mut probe).unwrap(),
        0,
        "connection must close after an unresyncable framing violation"
    );

    // (f) mid-frame truncation: announce 100 bytes, send 1, hang up
    let mut s3 = TcpStream::connect(&addr).unwrap();
    s3.write_all(&100u32.to_le_bytes()).unwrap();
    s3.write_all(&[tcp::OP_PING]).unwrap();
    drop(s3);

    // all six violations are counted (f lands asynchronously)
    let deadline = Instant::now() + Duration::from_secs(5);
    while coord.metrics.protocol_errors() < 6 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(coord.metrics.protocol_errors(), 6);
    assert!(coord.metrics.render().contains("6 protocol errors"));

    // and the server still accepts fresh connections
    let mut client = tcp::Client::connect(&addr).unwrap();
    client.ping().unwrap();
}

/// Satellite (preallocation DoS): a batch frame whose count field lies —
/// astronomically large, or zero — is answered with a clean err frame
/// before any allocation, the connection stays usable, and the violation
/// is counted.
#[test]
fn preallocation_lies_rejected() {
    let (coord, handle, _direct) = serve_mlp(BatchConfig::default());
    let addr = handle.addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();

    // count = 0xFFFF_FFFF in a 10-byte payload: would preallocate 4G
    // entries if trusted
    let mut p = Vec::new();
    p.extend_from_slice(&4u16.to_le_bytes());
    p.extend_from_slice(b"bmlp");
    p.extend_from_slice(&u32::MAX.to_le_bytes());
    s.write_all(&frame(tcp::OP_PREDICT_BATCH, &p)).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("impossible"),
        "{body:?}"
    );

    // count = 0: protocol misuse, not a degenerate empty success
    let mut p = Vec::new();
    p.extend_from_slice(&4u16.to_le_bytes());
    p.extend_from_slice(b"bmlp");
    p.extend_from_slice(&0u32.to_le_bytes());
    s.write_all(&frame(tcp::OP_PREDICT_BATCH, &p)).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_ERR);
    assert!(
        String::from_utf8_lossy(&body).contains("empty batch"),
        "{body:?}"
    );

    // the frame boundary was known in both cases: the stream is still in
    // sync and the connection still serves
    s.write_all(&frame(tcp::OP_PING, &[])).unwrap();
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_OK);
    assert_eq!(body, b"pong");

    assert_eq!(coord.metrics.protocol_errors(), 2);
}

/// Satellite (slow reader): a client that trickles its request in one
/// byte at a time must still get a correct reply — the event loop has to
/// accumulate partial frames across many EPOLLIN events without blocking
/// anyone else.
#[test]
fn one_byte_at_a_time() {
    let (_coord, handle, direct) = serve_mlp(BatchConfig::default());
    let addr = handle.addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_nodelay(true).unwrap();

    // a ping, then a real predict, each dribbled byte-by-byte
    let mut rng = Rng::new(31);
    let img = image(&mut rng);
    for req in [
        frame(tcp::OP_PING, &[]),
        frame(tcp::OP_PREDICT, &predict_payload("bmlp", &img)),
    ] {
        // flush a byte at a time for the envelope and the first bytes of
        // the payload (covers the len-split and op-split cases), then the
        // rest in small odd-sized chunks so the test stays fast
        for b in &req[..16.min(req.len())] {
            s.write_all(std::slice::from_ref(b)).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        if req.len() > 16 {
            for chunk in req[16..].chunks(97) {
                s.write_all(chunk).unwrap();
            }
        }
    }
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_OK);
    assert_eq!(body, b"pong");
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_OK);
    let want = direct.predict(&tensor(&img)).unwrap();
    assert_eq!(decode_scores(&body), want);
}

/// Satellite (partial writes): pipeline several maximum-size wire
/// batches without reading a single reply, let the server's responses
/// back up against a full socket buffer, then drain — every reply must
/// arrive complete and in request order. Exercises the event loop's
/// EPOLLOUT registration + write-resumption path.
#[test]
fn partial_writes_in_order() {
    const BATCHES: usize = 3;
    const PER_BATCH: usize = 1024;
    let (coord, handle, direct) = serve_mlp(BatchConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_depth: (BATCHES * PER_BATCH).max(1024),
        ..BatchConfig::default()
    });
    let addr = handle.addr().to_string();
    let mut s = TcpStream::connect(&addr).unwrap();

    let mut rng = Rng::new(59);
    let batches: Vec<Vec<Vec<u8>>> = (0..BATCHES)
        .map(|_| (0..PER_BATCH).map(|_| image(&mut rng)).collect())
        .collect();
    for imgs in &batches {
        let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
        s.write_all(&frame(tcp::OP_PREDICT_BATCH, &batch_payload("bmlp", &refs)))
            .unwrap();
    }
    // don't read yet: replies (~45 KB × 1024 per frame) must back up in
    // the kernel socket buffer and the server's write backlog
    std::thread::sleep(Duration::from_millis(300));

    for imgs in &batches {
        let (st, body) = read_reply(&mut s).unwrap();
        assert_eq!(st, tcp::STATUS_OK);
        let items = decode_batch_body(&body);
        assert_eq!(items.len(), PER_BATCH, "no reply lost or reordered");
        // oracle-check a sample of items per batch (the full cross-check
        // would dominate test runtime without adding coverage)
        for i in (0..PER_BATCH).step_by(101).chain([PER_BATCH - 1]) {
            let (st, item) = &items[i];
            assert_eq!(*st, tcp::STATUS_OK, "item {i}");
            let want = direct.predict(&tensor(&imgs[i])).unwrap();
            assert_eq!(decode_scores(item), want, "item {i}");
        }
    }
    let snap = coord.metrics.snapshot("bmlp").unwrap();
    assert_eq!(snap.requests, (BATCHES * PER_BATCH) as u64);
    assert_eq!(snap.rejected, 0, "queue_depth sized to admit everything");
}

/// Satellite (thread bound): waves of idle connection churn at c=256
/// must NOT move the serving-thread count — it stays bounded by the loop
/// count (+1 for the dispatching acceptor under `--acceptor single`;
/// the default reuseport layout has no acceptor thread at all), where
/// the retired threaded baseline would have spawned ~2 threads per
/// connection.
#[test]
fn event_idle_churn_256_connections_keeps_thread_count_flat() {
    const LOOPS: usize = 2;
    const WAVE: usize = 256;
    for acceptor in [tcp::Acceptor::Reuseport, tcp::Acceptor::Single] {
        let mut rng = Rng::new(4242);
        let spec = bmlp_spec(&mut rng, 64, 1);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Arc::new(Coordinator::new(BatchConfig::default()));
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt")));
        let handle = tcp::serve(
            coord.clone(),
            "127.0.0.1:0",
            tcp::ServeOptions {
                max_conns: 2 * WAVE,
                io_loops: LOOPS,
                acceptor,
                ..tcp::ServeOptions::default()
            },
        )
        .unwrap();
        let addr = handle.addr().to_string();
        let baseline_os = espresso::util::os_thread_count();

        for wave in 0..3 {
            let mut clients: Vec<tcp::Client> = (0..WAVE)
                .map(|i| {
                    tcp::Client::connect(&addr)
                        .unwrap_or_else(|e| panic!("{acceptor:?} wave {wave} conn {i}: {e}"))
                })
                .collect();
            for c in clients.iter_mut() {
                c.ping().unwrap();
            }
            // all 256 connections are live right now; the event front end
            // must still be running on its fixed thread pool
            assert!(
                handle.serving_threads() <= LOOPS + 1,
                "{acceptor:?}: serving threads grew with connections: {} (wave {wave})",
                handle.serving_threads()
            );
            drop(clients);
        }

        assert!(
            handle.serving_thread_peak() <= LOOPS + 1,
            "{acceptor:?}: peak serving threads {} exceeded {LOOPS} loops + acceptor",
            handle.serving_thread_peak()
        );
        // whole-process view (includes test harness + batcher threads):
        // churn must not have leaked OS threads
        if let (Some(before), Some(after)) = (baseline_os, espresso::util::os_thread_count()) {
            assert!(
                after <= before + 2,
                "{acceptor:?}: OS thread count grew across churn: {before} -> {after}"
            );
        }
    }
}

/// Regression (review): a single burst of pipelined inline frames larger
/// than the server's reply window (`MAX_PIPELINE` = 256) must all be
/// answered. The whole burst fits in one read, so the socket is drained
/// in a single EPOLLIN — frames past the window cap sit in the server's
/// read buffer, level-triggered EPOLLIN never re-fires for them, and an
/// all-inline burst produces no batcher completions to wake the
/// connection: the event loop has to re-parse after pumping frees window
/// slots. The half-close before reading additionally parks persistent
/// EPOLLRDHUP state on the connection while its window is saturated,
/// which previously busy-spun the loop at 100% CPU.
#[test]
fn burst_past_reply_window() {
    const BURST: usize = 300; // > MAX_PIPELINE = 256
    let (_coord, handle, direct) = serve_mlp(BatchConfig::default());
    let mut s = TcpStream::connect(&handle.addr().to_string()).unwrap();
    // a regression hangs the client forever; fail fast and loud instead
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    let mut rng = Rng::new(77);
    let img = image(&mut rng);
    let mut burst = Vec::new();
    for _ in 0..BURST {
        burst.extend_from_slice(&frame(tcp::OP_PING, &[]));
    }
    // a predict at the tail proves ordering survives the stalled window
    burst.extend_from_slice(&frame(tcp::OP_PREDICT, &predict_payload("bmlp", &img)));
    s.write_all(&burst).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();

    for i in 0..BURST {
        let (st, body) = read_reply(&mut s).unwrap_or_else(|e| panic!("reply {i}: {e}"));
        assert_eq!(st, tcp::STATUS_OK, "reply {i}");
        assert_eq!(body, b"pong", "reply {i}");
    }
    let (st, body) = read_reply(&mut s).unwrap();
    assert_eq!(st, tcp::STATUS_OK);
    assert_eq!(
        decode_scores(&body),
        direct.predict(&tensor(&img)).unwrap()
    );
    // clean EOF once every reply has been delivered
    let mut b = [0u8; 1];
    assert_eq!(s.read(&mut b).unwrap(), 0, "trailing bytes after last reply");
}

/// Satellite: `shutdown` wakes every loop immediately — no poll loop, no
/// hang waiting for a next connection.
#[test]
fn shutdown_is_prompt() {
    let (_coord, mut handle, _direct) = serve_mlp(BatchConfig::default());
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    client.ping().unwrap();
    drop(client);
    let t0 = Instant::now();
    handle.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "shutdown took {:?}",
        t0.elapsed()
    );
    assert_eq!(handle.serving_threads(), 0, "all serving threads joined");
}
