//! Registry / replication / hot-swap integration suite: replicated
//! engines behind least-loaded dispatch, the shared per-model admission
//! budget, `OP_LOAD_MODEL` over the wire, and the atomic version swap
//! under concurrent load — version-consistent replies, zero dropped
//! requests, old replica threads joined after the drain.

use espresso::coordinator::{tcp, BatchConfig, Coordinator, EngineLoader};
use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::tensor::{Shape, Tensor};
use espresso::util::rng::Rng;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Engine whose every reply carries its version (as the only score), so
/// a client can tell exactly which weight set served each request.
struct Versioned {
    version: f32,
    delay: Duration,
}

impl Versioned {
    fn new(version: f32, delay_ms: u64) -> Arc<Self> {
        Arc::new(Self {
            version,
            delay: Duration::from_millis(delay_ms),
        })
    }
}

impl Engine for Versioned {
    fn name(&self) -> String {
        format!("versioned-v{}", self.version)
    }

    fn input_shape(&self) -> Shape {
        Shape::vector(4)
    }

    fn predict(&self, _img: &Tensor<u8>) -> anyhow::Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(vec![self.version])
    }

    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<anyhow::Result<Vec<f32>>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        imgs.iter().map(|_| Ok(vec![self.version])).collect()
    }
}

/// Loader that fabricates a replica set from the *path* (its file stem
/// is the version number) — no file IO, so swap mechanics are tested in
/// isolation from the `.esp` format.
fn versioned_loader(replicas: usize, delay_ms: u64) -> EngineLoader {
    Arc::new(move |path: &Path| {
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow::anyhow!("bad path"))?;
        let version: f32 = stem.parse().map_err(|_| {
            anyhow::anyhow!("path stem {stem:?} is not a version number")
        })?;
        Ok((0..replicas)
            .map(|_| Versioned::new(version, delay_ms) as Arc<dyn Engine>)
            .collect())
    })
}

fn serve_versioned(
    replicas: usize,
    delay_ms: u64,
    queue_depth: usize,
) -> (Arc<Coordinator>, tcp::ServerHandle) {
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(200),
        queue_depth,
        ..BatchConfig::default()
    }));
    let engines: Vec<Arc<dyn Engine>> = (0..replicas)
        .map(|_| Versioned::new(1.0, delay_ms) as Arc<dyn Engine>)
        .collect();
    coord.register_with_loader("m", engines, versioned_loader(replicas, delay_ms));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    (coord, handle)
}

/// Tentpole acceptance: hot swap under concurrent load. Every reply is
/// version-consistent (1.0 or 2.0, never mixed or garbage), no request
/// is dropped or errored by the swap, replies per connection are
/// version-monotonic, new requests after the deploy returns are all
/// v2, and the old replicas' batcher threads are joined (drained), not
/// leaked.
#[test]
fn swap_under_load_zero_drops() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 60;
    let (coord, handle) = serve_versioned(2, 2, 4096);
    let addr = handle.addr().to_string();
    let threads_before = espresso::util::os_thread_count();

    let deployed_version = std::thread::scope(|s| {
        let workers: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut client = tcp::Client::connect(&addr).unwrap();
                    let mut seen = Vec::with_capacity(PER_CLIENT);
                    for r in 0..PER_CLIENT {
                        let scores = client
                            .predict("m", &[0u8; 4])
                            .unwrap_or_else(|e| panic!("conn {c} req {r} dropped: {e}"));
                        assert_eq!(scores.len(), 1, "conn {c} req {r}");
                        seen.push(scores[0]);
                    }
                    seen
                })
            })
            .collect();

        // let the flood establish, then swap mid-traffic over the wire
        std::thread::sleep(Duration::from_millis(50));
        let mut admin = tcp::Client::connect(&addr).unwrap();
        let version = admin.load_model("m", "/weights/2.esp").unwrap();

        // anything submitted after deploy returned must be served by v2
        let scores = admin.predict("m", &[0u8; 4]).unwrap();
        assert_eq!(scores, vec![2.0], "post-swap request served by old version");

        for (c, w) in workers.into_iter().enumerate() {
            let seen = w.join().unwrap();
            assert_eq!(seen.len(), PER_CLIENT, "conn {c} lost replies");
            let mut flipped = false;
            for (r, &v) in seen.iter().enumerate() {
                assert!(
                    v == 1.0 || v == 2.0,
                    "conn {c} req {r}: version-inconsistent reply {v}"
                );
                if v == 2.0 {
                    flipped = true;
                } else {
                    assert!(
                        !flipped,
                        "conn {c} req {r}: v1 reply AFTER a v2 reply — swap not atomic"
                    );
                }
            }
        }
        version
    });
    assert_eq!(deployed_version, 2);
    assert_eq!(coord.version("m"), Some(2));

    // zero drops, zero errors, all 8×60 + 1 admin requests accounted for
    let snap = coord.metrics.snapshot("m").unwrap();
    assert_eq!(snap.requests, (CLIENTS * PER_CLIENT) as u64 + 1);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.rejected, 0, "queue_depth sized to admit everything");

    // the v1 replicas' batcher threads drained and joined: thread count
    // is back to (at most) baseline + the short-lived deploy thread
    if let Some(before) = threads_before {
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match espresso::util::os_thread_count() {
                Some(after) if after <= before + 1 => break,
                _ if std::time::Instant::now() > deadline => {
                    panic!(
                        "old replica threads leaked: {before} -> {:?}",
                        espresso::util::os_thread_count()
                    );
                }
                _ => std::thread::sleep(Duration::from_millis(20)),
            }
        }
    }
}

/// `OP_LOAD_MODEL` error paths over the wire: unknown model, a model
/// registered without a loader, and a loader failure — all come back as
/// err frames, the connection stays usable, and the serving version is
/// untouched.
#[test]
fn op_load_model_error_paths() {
    let (coord, handle) = serve_versioned(2, 0, 1024);
    // a loaderless companion model
    coord.register("static", Versioned::new(7.0, 0));
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();

    let err = client.load_model("nope", "/weights/2.esp").unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");

    let err = client.load_model("static", "/weights/2.esp").unwrap_err();
    assert!(err.to_string().contains("without a loader"), "{err}");

    // loader failure: the path stem is not a version number
    let err = client.load_model("m", "/weights/garbage.esp").unwrap_err();
    assert!(err.to_string().contains("not a version number"), "{err}");

    // nothing flipped, and the connection still serves
    assert_eq!(coord.version("m"), Some(1));
    assert_eq!(client.predict("m", &[0u8; 4]).unwrap(), vec![1.0]);
    assert_eq!(client.predict("static", &[0u8; 4]).unwrap(), vec![7.0]);
}

/// End-to-end deploy from a REAL `.esp` file: exercises the mmap-backed
/// `format::load` inside the hot-swap path with a loader that compiles
/// NativeEngine replicas, exactly like `espresso serve` does.
#[test]
fn deploy_from_real_esp_file() {
    let dir = std::env::temp_dir().join(format!("espresso-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(91);
    let spec_a = bmlp_spec(&mut rng, 32, 1);
    let spec_b = bmlp_spec(&mut rng, 48, 1);
    let path_a = dir.join("a.esp");
    let path_b = dir.join("b.esp");
    spec_a.save(&path_a).unwrap();
    spec_b.save(&path_b).unwrap();

    let loader: EngineLoader = Arc::new(|p: &Path| {
        let spec = ModelSpec::load(p)?;
        let mut engines: Vec<Arc<dyn Engine>> = Vec::new();
        for _ in 0..2 {
            let net = Network::<u64>::from_spec(&spec, Backend::Binary)?;
            engines.push(Arc::new(NativeEngine::new(net, "opt")));
        }
        Ok(engines)
    });
    let coord = Arc::new(Coordinator::new(BatchConfig::default()));
    coord.register_with_loader("bmlp", loader(&path_a).unwrap(), loader.clone());
    assert_eq!(coord.replica_count("bmlp"), Some(2));

    let mut rng = Rng::new(92);
    let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
    let t = Tensor::from_vec(Shape::vector(784), img);
    let direct_a = NativeEngine::new(
        Network::<u64>::from_spec(&spec_a, Backend::Binary).unwrap(),
        "a",
    );
    let direct_b = NativeEngine::new(
        Network::<u64>::from_spec(&spec_b, Backend::Binary).unwrap(),
        "b",
    );
    assert_eq!(
        coord.predict("bmlp", t.clone()).unwrap(),
        direct_a.predict(&t).unwrap()
    );

    let v = coord.deploy("bmlp", &path_b).unwrap();
    assert_eq!(v, 2);
    assert_eq!(
        coord.predict("bmlp", t.clone()).unwrap(),
        direct_b.predict(&t).unwrap(),
        "post-deploy predictions must come from the new weights"
    );
    // failed deploys keep the current version serving
    assert!(coord.deploy("bmlp", &dir.join("missing.esp")).is_err());
    assert_eq!(coord.version("bmlp"), Some(2));
    assert_eq!(
        coord.predict("bmlp", t.clone()).unwrap(),
        direct_b.predict(&t).unwrap()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Least-loaded dispatch spreads concurrent traffic over every replica
/// (per-replica counters aggregate under the registered model name).
#[test]
fn least_loaded_distributes_across_replicas() {
    let (coord, handle) = serve_versioned(2, 20, 4096);
    let addr = handle.addr().to_string();
    std::thread::scope(|s| {
        for _ in 0..8 {
            let addr = addr.clone();
            s.spawn(move || {
                let mut client = tcp::Client::connect(&addr).unwrap();
                for _ in 0..4 {
                    assert_eq!(client.predict("m", &[0u8; 4]).unwrap(), vec![1.0]);
                }
            });
        }
    });
    let served = coord.metrics.replica_served("m");
    assert_eq!(served.len(), 2);
    assert_eq!(served.iter().sum::<u64>(), 32);
    assert!(
        served.iter().all(|&n| n > 0),
        "one replica starved: {served:?} — least-loaded dispatch not spreading"
    );
    // the rendered stats aggregate under "m" with a per-replica breakdown
    let stats = coord.metrics.render();
    assert!(stats.contains("replicas[m]"), "{stats}");
    assert!(
        coord.metrics.snapshot("versioned-v1").is_none(),
        "metrics must key by registered name, not engine label"
    );
}

/// `queue_depth` bounds the MODEL, not each replica: a 4-image batch
/// against queue_depth=2 with two idle slow replicas admits exactly 2 —
/// a per-replica budget would have admitted all 4.
#[test]
fn admission_budget_is_shared_across_replicas() {
    let (coord, handle) = serve_versioned(2, 600, 2);
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    let imgs: Vec<&[u8]> = vec![&[1, 0, 0, 0], &[2, 0, 0, 0], &[3, 0, 0, 0], &[4, 0, 0, 0]];
    let replies = client.predict_batch("m", &imgs).unwrap();
    let ok = replies
        .iter()
        .filter(|r| matches!(r, tcp::Reply::Scores(_)))
        .count();
    let overloaded = replies
        .iter()
        .filter(|r| matches!(r, tcp::Reply::Overloaded))
        .count();
    assert_eq!(
        (ok, overloaded),
        (2, 2),
        "shared budget must admit exactly queue_depth=2 of 4: {replies:?}"
    );
    let snap = coord.metrics.snapshot("m").unwrap();
    assert_eq!(snap.rejected, 2);
}

/// Engine that counts `trim_pools` calls — proves the idle-tick trim
/// reaches EVERY replica, not just replica 0.
struct Trimmable {
    trims: AtomicUsize,
}

impl Engine for Trimmable {
    fn name(&self) -> String {
        "trimmable".into()
    }

    fn input_shape(&self) -> Shape {
        Shape::vector(4)
    }

    fn predict(&self, _img: &Tensor<u8>) -> anyhow::Result<Vec<f32>> {
        Ok(vec![0.0])
    }

    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<anyhow::Result<Vec<f32>>> {
        imgs.iter().map(|_| Ok(vec![0.0])).collect()
    }

    fn trim_pools(&self) -> usize {
        self.trims.fetch_add(1, Ordering::SeqCst);
        3
    }
}

#[test]
fn trim_pools_reaches_every_replica() {
    let coord = Arc::new(Coordinator::new(BatchConfig::default()));
    let replicas: Vec<Arc<Trimmable>> = (0..3)
        .map(|_| {
            Arc::new(Trimmable {
                trims: AtomicUsize::new(0),
            })
        })
        .collect();
    coord.register_replicated(
        "t",
        replicas
            .iter()
            .map(|r| r.clone() as Arc<dyn Engine>)
            .collect(),
    );
    assert_eq!(coord.replica_count("t"), Some(3));
    let freed = coord.trim_pools();
    assert_eq!(freed, 9, "trim must sum over all 3 replicas");
    for (i, r) in replicas.iter().enumerate() {
        assert_eq!(
            r.trims.load(Ordering::SeqCst),
            1,
            "replica {i} was not trimmed"
        );
    }
}
