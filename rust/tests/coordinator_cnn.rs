//! Coordinator × batched-CNN integration: a `Batcher` in front of a CNN
//! engine under concurrent load must (a) return exactly the same scores
//! as direct single-image `predict` calls, and (b) actually form
//! multi-request batches (observable in `Metrics`), now that the native
//! CNN forward consumes a whole batch as one GEMM per layer.

use espresso::coordinator::{BatchConfig, Batcher, Metrics};
use espresso::layers::Backend;
use espresso::net::{bcnn_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::tensor::{Shape, Tensor};
use espresso::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Engine wrapper that inflates service time slightly so the test can
/// rely on queue build-up (and hence batching) under concurrent load,
/// independent of host speed.
struct Slowed {
    inner: NativeEngine,
    delay: Duration,
}

impl Engine for Slowed {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn input_shape(&self) -> Shape {
        self.inner.input_shape()
    }

    fn predict(&self, img: &Tensor<u8>) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.predict(img)
    }

    fn predict_batch(&self, imgs: &[&Tensor<u8>]) -> Vec<anyhow::Result<Vec<f32>>> {
        // one sleep per BATCH (not per request): batching amortizes it,
        // exactly like the GEMM amortizes packed-weight sweeps
        std::thread::sleep(self.delay);
        self.inner.predict_batch(imgs)
    }
}

#[test]
fn batcher_over_cnn_engine_matches_direct_and_batches() {
    let mut rng = Rng::new(221);
    let spec = bcnn_spec(&mut rng, 0.125); // 16/32/64-channel CIFAR CNN
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let direct = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
        "cnn-direct",
    );
    let engine = Arc::new(Slowed {
        inner: NativeEngine::new(net, "cnn"),
        delay: Duration::from_millis(3),
    });
    let metrics = Arc::new(Metrics::new());
    let batcher = Arc::new(Batcher::spawn(
        "cnn",
        engine,
        BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            ..BatchConfig::default()
        },
        metrics.clone(),
    ));

    let shape = Shape::new(32, 32, 3);
    let imgs: Vec<Tensor<u8>> = (0..32)
        .map(|_| {
            Tensor::from_vec(
                shape,
                (0..shape.len()).map(|_| rng.next_u32() as u8).collect(),
            )
        })
        .collect();

    // concurrent load: 4 client threads × 8 requests each
    let results: Vec<(usize, Vec<f32>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let batcher = batcher.clone();
            let imgs = &imgs;
            handles.push(s.spawn(move || {
                let mut out = Vec::new();
                for i in (t..32).step_by(4) {
                    let scores = batcher.predict(imgs[i].clone()).unwrap();
                    out.push((i, scores));
                }
                out
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    });

    // (a) every batched result equals the direct single-image prediction
    assert_eq!(results.len(), 32);
    for (i, scores) in &results {
        let want = direct.predict(&imgs[*i]).unwrap();
        assert_eq!(*scores, want, "request {i}");
    }

    // (b) metrics recorded real batches: fewer batches than requests
    // means at least one batch had size > 1
    let snap = metrics.snapshot("cnn").unwrap();
    assert_eq!(snap.requests, 32);
    assert!(snap.batches >= 1);
    assert!(
        snap.batches < snap.requests,
        "expected multi-request batches, got {} batches for {} requests",
        snap.batches,
        snap.requests
    );
    assert!(snap.mean_batch > 1.0, "mean batch {}", snap.mean_batch);
}
