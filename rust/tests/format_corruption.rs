//! Corruption corpus for the `.esp` weight format. The v4 trailer
//! (per-section CRC32s + length cross-checks) must reject every
//! truncation and every single-bit flip with a typed `IntegrityError` —
//! and must never panic — on both load paths (heap `read_from` and the
//! mmap-backed file `load`). Legacy v2/v3 files carry no checksums, so
//! for them the bar is "never panics": a flip may parse, may error, but
//! must not take the process down.

use espresso::format::{IntegrityError, ModelSpec, FORMAT_VERSION};
use espresso::net::bmlp_spec;
use espresso::util::rng::Rng;
use std::path::PathBuf;

fn spec() -> ModelSpec {
    let mut rng = Rng::new(5150);
    bmlp_spec(&mut rng, 32, 2)
}

fn v4_bytes() -> Vec<u8> {
    let mut buf = Vec::new();
    spec().write_to(&mut buf).unwrap();
    buf
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

/// Load `bytes` through the mmap path by writing them to a scratch file.
fn load_file(path: &PathBuf, bytes: &[u8]) -> anyhow::Result<ModelSpec> {
    std::fs::write(path, bytes).unwrap();
    ModelSpec::load(path)
}

/// Every prefix truncation of a v4 file must fail the integrity check —
/// no prefix may parse as a valid model. In-memory path, every length.
#[test]
fn v4_truncation_never_parses_in_memory() {
    let full = v4_bytes();
    for cut in 0..full.len() {
        let res = ModelSpec::read_from(&mut &full[..cut]);
        assert!(res.is_err(), "truncation to {cut}/{} parsed", full.len());
    }
}

/// File-path (mmap) truncation sweep at structural boundaries and a
/// sample of interior cuts; always a typed `IntegrityError` once the
/// trailer region is damaged, always SOME error otherwise.
#[test]
fn v4_truncation_never_loads_from_file() {
    let full = v4_bytes();
    let path = tmp("espresso_corrupt_trunc.esp");
    let mut cuts: Vec<usize> = (0..full.len()).step_by(257).collect();
    cuts.extend([
        0,
        4,
        full.len() - 1,
        full.len() - 4,
        full.len() - 9,
        full.len().saturating_sub(16),
    ]);
    for cut in cuts {
        let res = load_file(&path, &full[..cut]);
        assert!(res.is_err(), "file truncated to {cut}/{} loaded", full.len());
    }
    // a cut inside the body (trailer gone) is the torn-write shape: it
    // must carry the typed error so deploy failures count in metrics
    let err = load_file(&path, &full[..full.len() / 2]).unwrap_err();
    assert!(
        err.downcast_ref::<IntegrityError>().is_some(),
        "torn write is a typed integrity error: {err:#}"
    );
    let _ = std::fs::remove_file(&path);
}

/// Every single-bit flip in a v4 file must fail to load, and must never
/// panic. Exhaustive over bytes (one rotating bit position per byte) on
/// the in-memory path.
#[test]
fn v4_bit_flips_never_parse_in_memory() {
    let mut bytes = v4_bytes();
    assert_eq!(bytes[4], FORMAT_VERSION as u8);
    for i in 0..bytes.len() {
        let bit = 1u8 << (i % 8);
        bytes[i] ^= bit;
        let res = ModelSpec::read_from(&mut bytes.as_slice());
        assert!(res.is_err(), "bit flip at byte {i} (mask {bit:#04x}) parsed");
        bytes[i] ^= bit;
    }
    // pristine bytes still parse after the sweep (the flips restored)
    ModelSpec::read_from(&mut bytes.as_slice()).unwrap();
}

/// Sampled single-bit flips through the mmap file path: rejected, never
/// a panic, and checksum damage carries the typed error.
#[test]
fn v4_bit_flips_never_load_from_file() {
    let mut bytes = v4_bytes();
    let path = tmp("espresso_corrupt_flip.esp");
    let positions: Vec<usize> = (0..bytes.len()).step_by(101).collect();
    for i in positions {
        let bit = 1u8 << (i % 8);
        bytes[i] ^= bit;
        let res = load_file(&path, &bytes);
        assert!(res.is_err(), "file bit flip at byte {i} loaded");
        bytes[i] ^= bit;
    }
    // deep-body flip: caught only by the section CRC, so the error must
    // be the typed one with the section coordinates
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    let err = load_file(&path, &bytes).unwrap_err();
    assert!(
        err.downcast_ref::<IntegrityError>().is_some(),
        "CRC failure is typed: {err:#}"
    );
    bytes[mid] ^= 0x40;
    load_file(&path, &bytes).unwrap();
    let _ = std::fs::remove_file(&path);
}

/// Legacy v3 files carry no trailer: corruption there may or may not
/// parse, but must NEVER panic, on either path. (Catching unwinds is
/// not possible across the mmap internals, so "the test completes" is
/// the assertion.)
#[test]
fn v3_corruption_never_panics() {
    let mut buf = Vec::new();
    spec().write_to_version(&mut buf, 3).unwrap();
    let path = tmp("espresso_corrupt_v3.esp");
    // truncations
    for cut in (0..buf.len()).step_by(509) {
        let _ = ModelSpec::read_from(&mut &buf[..cut]);
        let _ = load_file(&path, &buf[..cut]);
    }
    // bit flips (restore after each so damage doesn't compound)
    let mut bytes = buf.clone();
    for i in (0..bytes.len()).step_by(379) {
        let bit = 1u8 << (i % 8);
        bytes[i] ^= bit;
        let _ = ModelSpec::read_from(&mut bytes.as_slice());
        let _ = load_file(&path, &bytes);
        bytes[i] ^= bit;
    }
    // the pristine v3 file still loads (compat path intact)
    load_file(&path, &buf).unwrap();
    let _ = std::fs::remove_file(&path);
}
