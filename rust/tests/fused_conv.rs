//! The fused-conv property suite — the contract that locks in the
//! tile-streaming convolution refactor: for ANY architecture, ANY batch
//! size, ANY word width and ANY per-layer backend placement, the fused
//! forward (tile-streamed unroll panels feeding the GEMM micro-kernel,
//! image-group tails) must be **bit-identical** to the materialized
//! oracle (`Network::forward_materialized` — the pre-fusion semantics:
//! full `(B·oh·ow) × k` patch matrix + one GEMM per layer).
//!
//! This holds exactly because tiling changes only *when* patch rows
//! exist, never their contents or the per-row accumulation order; the
//! binary paths are integer-exact and the float micro-kernel computes the
//! same dot over the same row either way.
//!
//! The suite also pins the refactor's memory story: fused conv scratch
//! reservations must undercut the materialized ones ≥ 4× at B = 64 on
//! the t3 CNN (ISSUE 3 acceptance).

use espresso::format::sample;
use espresso::layers::{Act, Backend};
use espresso::net::Network;
use espresso::tensor::Tensor;
use espresso::util::prop::check_simple;
use espresso::util::rng::Rng;

fn random_images(rng: &mut Rng, spec: &espresso::format::ModelSpec, n: usize) -> Vec<Tensor<u8>> {
    (0..n)
        .map(|_| {
            Tensor::from_vec(
                spec.input_shape,
                (0..spec.input_shape.len())
                    .map(|_| rng.next_u32() as u8)
                    .collect(),
            )
        })
        .collect()
}

/// Scores from the materialized-oracle forward on one image.
fn materialized_scores<W: espresso::bitpack::Word>(
    net: &Network<W>,
    img: &Tensor<u8>,
) -> Vec<f32> {
    net.forward_materialized(Act::Bytes(img.clone()))
        .into_float()
        .data
}

/// Per-image scores from the materialized-oracle forward on a stacked
/// batch.
fn materialized_batch_scores<W: espresso::bitpack::Word>(
    net: &Network<W>,
    imgs: &[&Tensor<u8>],
) -> Vec<Vec<f32>> {
    let out = net
        .forward_materialized(Act::Bytes(Tensor::stack(imgs)))
        .into_float();
    let per = out.data.len() / imgs.len();
    (0..imgs.len())
        .map(|i| out.data[i * per..(i + 1) * per].to_vec())
        .collect()
}

/// Core property: fused forward == materialized oracle, bit for bit, on
/// random specs (asymmetric kernels, stride up to 3, padded and unpadded
/// convs, both first-layer byte strategies) under both uniform backends,
/// single and batched.
#[test]
fn prop_fused_equals_materialized_uniform_backends() {
    check_simple(
        "fused-equals-materialized",
        24,
        331,
        |r| (r.next_u64(), 1 + r.below(5)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            for backend in [Backend::Binary, Backend::Float] {
                let net = Network::<u64>::from_spec(&spec, backend).unwrap();
                for img in &imgs {
                    if net.predict_bytes(img) != materialized_scores(&net, img) {
                        return false;
                    }
                }
                let batched = net.predict_batch_bytes(&refs);
                let oracle = materialized_batch_scores(&net, &refs);
                if batched != oracle {
                    return false;
                }
            }
            true
        },
    );
}

/// Random hybrid placements: per-layer Float/Binary mixes must stay
/// bit-identical through the fused path.
#[test]
fn prop_fused_equals_materialized_hybrid_placements() {
    check_simple(
        "fused-equals-materialized-hybrid",
        16,
        332,
        |r| (r.next_u64(), 2 + r.below(3)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let mut net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let placement: Vec<Backend> = (0..net.layer_count())
                .map(|_| {
                    if rng.bernoulli(0.5) {
                        Backend::Binary
                    } else {
                        Backend::Float
                    }
                })
                .collect();
            net.set_backends(&placement);
            for img in &imgs {
                if net.predict_bytes(img) != materialized_scores(&net, img) {
                    return false;
                }
            }
            net.predict_batch_bytes(&refs) == materialized_batch_scores(&net, &refs)
        },
    );
}

/// u32 packing satisfies the same equivalence (the A4 width comparison
/// measures identical code paths through the fused kernels).
#[test]
fn prop_fused_equals_materialized_u32_words() {
    check_simple(
        "fused-equals-materialized-u32",
        12,
        333,
        |r| (r.next_u64(), 1 + r.below(4)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let net = Network::<u32>::from_spec(&spec, Backend::Binary).unwrap();
            for img in &imgs {
                if net.predict_bytes(img) != materialized_scores(&net, img) {
                    return false;
                }
            }
            net.predict_batch_bytes(&refs) == materialized_batch_scores(&net, &refs)
        },
    );
}

/// The image-group streaming seam: small random specs always fit one
/// group (`group_images == batch`), so this case forces `group < batch`
/// — conv1/conv2 of the half-width t3 CNN carry a 32×32×64 accumulator
/// (256 KiB/image against the 1 MiB group budget → groups of 4), and
/// batch 5 adds a partial final group. Exercises the group-offset
/// arithmetic in the streamed tails that single-group runs never touch.
#[test]
fn multi_group_streaming_equals_materialized() {
    let mut rng = Rng::new(337);
    let spec = espresso::net::bcnn_spec(&mut rng, 0.5);
    // premise guard: the first conv stages must stream in > 1 group at
    // batch 5 (fails loudly if the budget or the arch changes)
    let per_image_acc_bytes = 32 * 32 * 64 * 4;
    assert!(
        (1usize << 20) / per_image_acc_bytes < 5,
        "spec no longer forces multiple image groups"
    );
    let imgs = random_images(&mut rng, &spec, 5);
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    for backend in [Backend::Binary, Backend::Float] {
        let net = Network::<u64>::from_spec(&spec, backend).unwrap();
        let batched = net.predict_batch_bytes(&refs);
        let oracle = materialized_batch_scores(&net, &refs);
        assert_eq!(batched, oracle, "{backend:?} multi-group seam");
    }
}

/// ISSUE 3 acceptance: on the t3 CNN at B = 64, the fused path's peak
/// conv scratch reservation must be ≥ 4× smaller than the materialized
/// oracle's — the tile-streaming memory win, measured on the exact specs
/// `Network::reserve` uses for the pools.
#[test]
fn t3_cnn_conv_scratch_shrinks_at_least_4x_at_b64() {
    let mut rng = Rng::new(334);
    for width in [0.25f32, 1.0] {
        let spec = espresso::net::bcnn_spec(&mut rng, width);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let report = net.scratch_report(64);
        let conv_rows: Vec<_> = report
            .iter()
            .filter(|(name, _, _)| name.starts_with("Conv"))
            .collect();
        assert!(!conv_rows.is_empty(), "no conv steps in {report:?}");
        let peak_fused = conv_rows.iter().map(|r| r.1).max().unwrap();
        let peak_mat = conv_rows.iter().map(|r| r.2).max().unwrap();
        assert!(
            peak_mat >= 4 * peak_fused,
            "width {width}: conv peak scratch fused {peak_fused} B vs materialized \
             {peak_mat} B — expected ≥ 4× reduction"
        );
        // every conv step individually must not regress
        for (name, fused, mat) in &conv_rows {
            assert!(
                fused <= mat,
                "{name}: fused scratch {fused} B exceeds materialized {mat} B"
            );
        }
    }
}

/// The executor's peak-scratch profiling surfaces the same numbers
/// through `PlanProfile` (what `espresso profile` and the coordinator
/// render) once a batched forward has run.
#[test]
fn plan_profile_records_peak_scratch() {
    let mut rng = Rng::new(335);
    let spec = espresso::net::mnist_cnn_spec(&mut rng, 0.5);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let imgs = random_images(&mut rng, &spec, 16);
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    net.reserve(16);
    let _ = net.predict_batch_bytes(&refs);
    let prof = net.profile();
    let conv = &prof.rows[0];
    assert_eq!(conv.peak_batch, 16, "{conv:?}");
    assert!(conv.peak_scratch_bytes > 0, "{conv:?}");
    assert!(
        conv.peak_scratch_materialized_bytes > conv.peak_scratch_bytes,
        "conv step should report a fused memory win: {conv:?}"
    );
    assert!(prof.peak_scratch_materialized_bytes() >= prof.peak_scratch_bytes());
    assert!(prof.render().contains("scratch@B"), "{}", prof.render());
}

/// Fused forwards draw every buffer from reserved pools: after
/// `reserve(batch)`, steady-state batched forwards perform zero pool
/// misses — the tile panels, group accumulators and pooled buffers all
/// have exact freelist counterparts.
#[test]
fn prop_fused_reserved_forwards_never_miss_the_pool() {
    check_simple(
        "fused-reserved-no-misses",
        12,
        336,
        |r| (r.next_u64(), 1 + r.below(6)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample_cnn(&mut rng);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            net.reserve(batch);
            let before = net.ws.stats_total();
            let _ = net.predict_batch_bytes(&refs);
            let _ = net.predict_batch_bytes(&refs);
            let after = net.ws.stats_total();
            after.misses == before.misses && after.hits > before.hits
        },
    );
}
