//! Persistent worker-pool suite (ISSUE 5): the kernel scheduler must be
//! deterministic across thread counts, survive poisoned job bodies, and
//! spawn zero OS threads on the steady-state forward path.
//!
//! Every test serializes on one lock because `set_num_threads_for_test`
//! and the spawn counter are process-global; this file is its own test
//! binary, so the rest of the suite is unaffected.

use espresso::layers::Backend;
use espresso::net::{mnist_cnn_spec, Network};
use espresso::tensor::Tensor;
use espresso::util::parallel::{self, DispatchMode};
use espresso::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // a panicking test must not wedge the rest of the file
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn cnn_and_images(seed: u64) -> (Network<u64>, Vec<Tensor<u8>>) {
    let mut rng = Rng::new(seed);
    let spec = mnist_cnn_spec(&mut rng, 0.25);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let imgs: Vec<Tensor<u8>> = (0..6)
        .map(|_| {
            Tensor::from_vec(
                spec.input_shape,
                (0..spec.input_shape.len())
                    .map(|_| rng.next_u32() as u8)
                    .collect(),
            )
        })
        .collect();
    (net, imgs)
}

/// N concurrent forwards × M pool threads must be bit-identical to the
/// single-threaded scheduler — dynamic chunk claiming and the busy-pool
/// inline fallback may change *who* computes a chunk, never *what*.
#[test]
fn concurrent_forwards_bit_identical_vs_single_thread() {
    let _g = lock();
    let (net, imgs) = cnn_and_images(7001);
    parallel::set_num_threads_for_test(1);
    let reference: Vec<Vec<f32>> = imgs.iter().map(|i| net.predict_bytes(i)).collect();
    parallel::set_num_threads_for_test(4);
    parallel::ensure_started(4);
    std::thread::scope(|s| {
        for t in 0..4 {
            let net = &net;
            let imgs = &imgs;
            let reference = &reference;
            s.spawn(move || {
                for round in 0..4 {
                    for (i, img) in imgs.iter().enumerate() {
                        assert_eq!(
                            net.predict_bytes(img),
                            reference[i],
                            "thread {t} round {round} image {i}"
                        );
                    }
                }
            });
        }
    });
    // the batched path goes through the same pool
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    assert_eq!(net.predict_batch_bytes(&refs), reference);
    // and back at one thread the answers are unchanged
    parallel::set_num_threads_for_test(1);
    let again: Vec<Vec<f32>> = imgs.iter().map(|i| net.predict_bytes(i)).collect();
    assert_eq!(again, reference);
    parallel::set_num_threads_for_test(4);
}

/// A panicking job body reaches the caller as a panic, the surviving
/// chunks still execute on the other workers, and the pool itself
/// survives — no worker dies, no respawn, later jobs run normally.
#[test]
fn pool_survives_panicking_job_bodies() {
    let _g = lock();
    parallel::set_num_threads_for_test(4);
    parallel::ensure_started(4);
    // warm the pool so the spawn counter is in steady state
    parallel::parallel_for_chunks(1 << 12, 1, |_, _| {});
    let spawned = parallel::spawn_count();
    for round in 0..3 {
        let r = std::panic::catch_unwind(|| {
            parallel::parallel_for_dynamic(256, |i| {
                if i % 97 == 13 {
                    panic!("poisoned job body at {i}");
                }
            });
        });
        assert!(r.is_err(), "round {round}: the panic must reach the caller");
    }
    assert_eq!(
        parallel::spawn_count(),
        spawned,
        "poisoned jobs must not kill (and respawn) pool workers"
    );
    // full coverage afterwards: the pool is not wedged or depleted
    let sum = AtomicU64::new(0);
    parallel::parallel_for_chunks(10_000, 8, |a, b| {
        sum.fetch_add((b - a) as u64, Ordering::Relaxed);
    });
    assert_eq!(sum.load(Ordering::Relaxed), 10_000);
}

/// The acceptance bar: after warmup, whole forwards — serial, batched,
/// and concurrent from several request threads — spawn zero OS threads.
#[test]
fn zero_thread_spawns_after_warmup() {
    let _g = lock();
    parallel::set_num_threads_for_test(4);
    parallel::ensure_started(4);
    let (net, imgs) = cnn_and_images(7002);
    net.reserve(1);
    net.reserve(imgs.len());
    // warmup: prime pool workers, buffer pools, and affinity slots
    let _ = net.predict_bytes(&imgs[0]);
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    let _ = net.predict_batch_bytes(&refs);
    let spawns = parallel::spawn_count();
    std::thread::scope(|s| {
        for _ in 0..3 {
            let net = &net;
            let imgs = &imgs;
            s.spawn(move || {
                for img in imgs {
                    let _ = net.predict_bytes(img);
                }
            });
        }
    });
    let _ = net.predict_batch_bytes(&refs);
    assert_eq!(
        parallel::spawn_count(),
        spawns,
        "steady-state forwards must not spawn threads"
    );
    let status = parallel::pool_status();
    assert!(
        status.workers_alive >= 3,
        "pool workers stay parked between forwards: {status:?}"
    );
    assert!(status.jobs > 0, "forwards ran on the pool: {status:?}");
}

/// `set_num_threads_for_test` is a deterministic override: it replaces
/// the cached env/core-count value, the pool resizes against it, and it
/// bounds the reservation-facing `max_workers_for`.
#[test]
fn thread_count_override_is_deterministic() {
    let _g = lock();
    parallel::set_num_threads_for_test(3);
    parallel::ensure_started(parallel::num_threads());
    assert_eq!(parallel::num_threads(), 3);
    assert!(parallel::max_workers_for(1 << 22, 1) <= 3);
    assert!(
        parallel::pool_status().workers_alive >= 2,
        "pool resized to match the override"
    );
    // clamped to the hard cap (no eager growth: nothing dispatched)
    parallel::set_num_threads_for_test(parallel::MAX_WORKERS * 4);
    assert_eq!(parallel::num_threads(), parallel::MAX_WORKERS);
    // shrinking takes effect for scheduling without killing workers
    parallel::set_num_threads_for_test(2);
    assert_eq!(parallel::num_threads(), 2);
    assert!(parallel::max_workers_for(1 << 22, 1) <= 2);
    parallel::set_num_threads_for_test(4);
}

/// Concurrent kernel calls from several request threads: whoever loses
/// the pool race runs inline, everyone computes the right answer, and
/// the process doesn't deadlock.
#[test]
fn concurrent_jobs_degrade_gracefully() {
    let _g = lock();
    parallel::set_num_threads_for_test(4);
    parallel::ensure_started(4);
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for _ in 0..50 {
                    let sum = AtomicU64::new(0);
                    parallel::parallel_for_chunks(4096, 1, |a, b| {
                        let mut local = 0u64;
                        for i in a..b {
                            local += i as u64;
                        }
                        sum.fetch_add(local, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 4096u64 * 4095 / 2);
                }
            });
        }
    });
}

/// The legacy spawn-per-call scheduler (the latency-bench baseline) still
/// produces identical results and actually spawns.
#[test]
fn spawn_mode_baseline_still_works() {
    let _g = lock();
    parallel::set_num_threads_for_test(4);
    let (net, imgs) = cnn_and_images(7003);
    parallel::set_dispatch_mode_for_bench(DispatchMode::Pool);
    let want: Vec<Vec<f32>> = imgs.iter().map(|i| net.predict_bytes(i)).collect();
    parallel::set_dispatch_mode_for_bench(DispatchMode::Spawn);
    let got: Vec<Vec<f32>> = imgs.iter().map(|i| net.predict_bytes(i)).collect();
    parallel::set_dispatch_mode_for_bench(DispatchMode::Pool);
    assert_eq!(got, want, "dispatch mode must never change numerics");
}
