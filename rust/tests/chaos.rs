//! Chaos suite: fault-injected serving under failure. Uses the
//! `espresso::util::fault` registry (also reachable via the
//! `ESPRESSO_FAULT` env var) to drive panics, stalls, corrupt loads and
//! partial writes through the real serving stack, and asserts the
//! supervision/deadline/integrity machinery contains each fault:
//!
//! - a panicking batch fails only its own requests and is counted
//! - a poisoned replica set is rebuilt by the per-model supervisor
//! - queued requests past their deadline are shed with the dedicated
//!   wire status, not served late and not dropped
//! - a corrupt `.esp` deploy is rejected (typed integrity error, counted
//!   in metrics) while the old version keeps serving
//! - a partially-written weight file never loads
//! - `OP_HEALTH` reports per-model replica liveness
//! - `OP_DRAIN` stops admission, answers in-flight work, and quiesces
//!   every serving thread
//! - a soak run under combined panic + stall injection answers every
//!   request exactly once with a valid status, stays bit-identical to a
//!   direct-engine oracle on successes, and leaves the replica set whole
//!
//! The fault registry is process-global, so every test here serializes
//! on one mutex and disarms on the way out.

use anyhow::Result;
use espresso::coordinator::{tcp, BatchConfig, Coordinator, EngineLoader};
use espresso::format::{IntegrityError, ModelSpec};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::tensor::{Shape, Tensor};
use espresso::util::fault;
use espresso::util::rng::Rng;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

static LOCK: Mutex<()> = Mutex::new(());

fn guard() -> std::sync::MutexGuard<'static, ()> {
    let g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm_all();
    g
}

const INPUT: usize = 784;

fn image(rng: &mut Rng) -> Vec<u8> {
    (0..INPUT).map(|_| rng.next_u32() as u8).collect()
}

fn tensor(img: &[u8]) -> Tensor<u8> {
    Tensor::from_vec(Shape::vector(img.len()), img.to_vec())
}

/// Coordinator + direct oracle over one small binary MLP, `replicas`
/// engine replicas behind the dispatcher.
fn mlp_coord(cfg: BatchConfig, replicas: usize) -> (Arc<Coordinator>, NativeEngine) {
    let mut rng = Rng::new(9100);
    let spec = bmlp_spec(&mut rng, 64, 1);
    let coord = Arc::new(Coordinator::new(cfg));
    let engines: Vec<Arc<dyn Engine>> = (0..replicas)
        .map(|_| {
            let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            Arc::new(NativeEngine::new(net, "opt")) as Arc<dyn Engine>
        })
        .collect();
    coord.register_replicated("bmlp", engines);
    let direct = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    (coord, NativeEngine::new(direct, "direct"))
}

/// Engine that sleeps per prediction: makes queues form so deadline
/// shedding has something to shed.
struct SlowEngine(Duration);

impl Engine for SlowEngine {
    fn name(&self) -> String {
        "slow".into()
    }
    fn input_shape(&self) -> Shape {
        Shape::vector(4)
    }
    fn predict(&self, img: &Tensor<u8>) -> Result<Vec<f32>> {
        std::thread::sleep(self.0);
        Ok(vec![img.data[0] as f32])
    }
}

/// A panicking batch must fail only its own requests — the batcher
/// thread survives (`catch_unwind`), later requests succeed, and the
/// panic is counted under the model's metrics.
#[test]
fn panicking_batch_fails_only_its_requests() {
    let _g = guard();
    let (coord, direct) = mlp_coord(BatchConfig::default(), 1);
    let mut rng = Rng::new(9101);
    let img = image(&mut rng);
    let want = direct.predict(&tensor(&img)).unwrap();
    assert_eq!(coord.predict("bmlp", tensor(&img)).unwrap(), want);
    // fire on exactly the next batch
    fault::arm("panic-batch", 0, 1);
    let err = coord.predict("bmlp", tensor(&img)).unwrap_err();
    assert!(
        err.to_string().contains("panic"),
        "panicked batch surfaces as an error, got: {err:#}"
    );
    // the batcher is still alive and numerically unchanged
    for _ in 0..5 {
        assert_eq!(coord.predict("bmlp", tensor(&img)).unwrap(), want);
    }
    assert_eq!(coord.metrics.panics("bmlp"), 1);
    assert_eq!(coord.metrics.replica_restarts("bmlp"), 0);
    fault::disarm_all();
}

/// Enough consecutive panics poison the replica; the per-model
/// supervisor detects it, rebuilds the replica set from the current
/// version, and service recovers without re-registration.
#[test]
fn supervisor_rebuilds_poisoned_replica() {
    let _g = guard();
    let (coord, direct) = mlp_coord(BatchConfig::default(), 1);
    let mut rng = Rng::new(9102);
    let img = image(&mut rng);
    let want = direct.predict(&tensor(&img)).unwrap();
    assert_eq!(coord.predict("bmlp", tensor(&img)).unwrap(), want);
    // three consecutive panicking batches poison the only replica
    fault::arm("panic-batch", 0, 3);
    for _ in 0..3 {
        assert!(coord.predict("bmlp", tensor(&img)).is_err());
    }
    // the supervisor ticks asynchronously: poll until the rebuilt
    // replica answers again
    let t0 = Instant::now();
    let recovered = loop {
        if let Ok(scores) = coord.predict("bmlp", tensor(&img)) {
            break scores;
        }
        if t0.elapsed() > Duration::from_secs(10) {
            panic!(
                "replica not rebuilt after 10s (restarts={}, health={:?})",
                coord.metrics.replica_restarts("bmlp"),
                coord.health()
            );
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(recovered, want, "rebuilt replica is numerically identical");
    assert!(coord.metrics.panics("bmlp") >= 3);
    assert!(coord.metrics.replica_restarts("bmlp") >= 1);
    // version number did not change: a heal is not a deploy
    assert_eq!(coord.version("bmlp"), Some(1));
    let h = &coord.health()[0];
    assert_eq!((h.alive, h.replicas), (1, 1), "replica set whole again");
    fault::disarm_all();
}

/// Requests still queued when their deadline passes are shed with the
/// dedicated wire status (3), distinct from `overloaded`; requests that
/// made it into execution before the deadline still answer.
#[test]
fn deadline_shedding_over_the_wire() {
    let _g = guard();
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_depth: 1024,
        request_timeout: None,
    }));
    coord.register("slow", Arc::new(SlowEngine(Duration::from_millis(50))));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    let imgs: Vec<Vec<u8>> = (0..8).map(|i| vec![i as u8, 0, 0, 0]).collect();
    let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
    // 8 requests × 50 ms on one replica with a 25 ms client deadline:
    // the head of the queue executes, the tail expires while waiting
    let replies = client.predict_batch_deadline("slow", &refs, Some(25)).unwrap();
    assert_eq!(replies.len(), 8);
    let shed = replies
        .iter()
        .filter(|r| matches!(r, tcp::Reply::DeadlineExceeded))
        .count();
    let served = replies
        .iter()
        .filter(|r| matches!(r, tcp::Reply::Scores(_)))
        .count();
    assert_eq!(shed + served, 8, "every item answered: {replies:?}");
    assert!(shed >= 4, "most of the queue must be shed, got {shed}");
    assert!(served >= 1, "the head of the queue still answers");
    // the batcher records the shed count right after sending the last
    // reply; give that store a moment before the exact-count assert
    let t0 = Instant::now();
    while coord.metrics.deadline_exceeded("slow") < shed as u64
        && t0.elapsed() < Duration::from_secs(1)
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(coord.metrics.deadline_exceeded("slow"), shed as u64);
}

/// The server-side `request_timeout` sheds without any client deadline
/// on the wire.
#[test]
fn server_side_request_timeout_sheds() {
    let _g = guard();
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch: 1,
        max_wait: Duration::from_micros(100),
        queue_depth: 1024,
        request_timeout: Some(Duration::from_millis(15)),
    }));
    coord.register("slow", Arc::new(SlowEngine(Duration::from_millis(50))));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    let imgs: Vec<Vec<u8>> = (0..8).map(|i| vec![i as u8, 0, 0, 0]).collect();
    let refs: Vec<&[u8]> = imgs.iter().map(|i| i.as_slice()).collect();
    let replies = client.predict_batch("slow", &refs).unwrap();
    let shed = replies
        .iter()
        .filter(|r| matches!(r, tcp::Reply::DeadlineExceeded))
        .count();
    assert!(shed >= 4, "server-side timeout must shed the tail: {replies:?}");
    assert!(coord.metrics.deadline_exceeded("slow") >= shed as u64);
}

/// A deploy whose load fails the integrity check is rejected with the
/// typed error, counted, and leaves the old version serving untouched.
#[test]
fn corrupt_deploy_keeps_old_version_serving() {
    let _g = guard();
    let dir = std::env::temp_dir();
    let path = dir.join("espresso_chaos_deploy.esp");
    let mut rng = Rng::new(9103);
    let spec = bmlp_spec(&mut rng, 64, 1);
    spec.save(&path).unwrap();
    let loader: EngineLoader = Arc::new(|p: &Path| {
        let spec = ModelSpec::load(p)?;
        let net = Network::<u64>::from_spec(&spec, Backend::Binary)?;
        Ok(vec![Arc::new(NativeEngine::new(net, "opt")) as Arc<dyn Engine>])
    });
    let coord = Coordinator::new(BatchConfig::default());
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    coord.register_with_loader("m", vec![Arc::new(NativeEngine::new(net, "opt"))], loader);
    let img = tensor(&image(&mut rng));
    let before = coord.predict("m", img.clone()).unwrap();
    let rejects_before = coord.metrics.integrity_rejects();
    // the next load reports a checksum failure
    fault::arm("corrupt-load", 0, 1);
    let err = coord.deploy("m", &path).unwrap_err();
    assert!(
        err.downcast_ref::<IntegrityError>().is_some(),
        "deploy failure is the typed integrity error: {err:#}"
    );
    assert_eq!(coord.metrics.integrity_rejects(), rejects_before + 1);
    assert_eq!(coord.version("m"), Some(1), "failed deploy must not bump");
    assert_eq!(
        coord.predict("m", img.clone()).unwrap(),
        before,
        "old version still serving, numerically unchanged"
    );
    // with the fault dry, the same deploy succeeds
    assert_eq!(coord.deploy("m", &path).unwrap(), 2);
    assert_eq!(coord.predict("m", img).unwrap(), before);
    fault::disarm_all();
    let _ = std::fs::remove_file(&path);
}

/// A partially-written weight file (simulated torn write at save time)
/// must never load — the checksum trailer catches the truncation.
#[test]
fn partial_write_never_loads() {
    let _g = guard();
    let dir = std::env::temp_dir();
    let path = dir.join("espresso_chaos_partial.esp");
    let mut rng = Rng::new(9104);
    let spec = bmlp_spec(&mut rng, 64, 1);
    fault::arm("partial-write", 0, 1);
    spec.save(&path).unwrap(); // truncated behind our back
    let err = ModelSpec::load(&path).unwrap_err();
    assert!(
        err.downcast_ref::<IntegrityError>().is_some(),
        "torn file rejected with the typed error: {err:#}"
    );
    fault::disarm_all();
    // a clean save of the same spec loads fine
    spec.save(&path).unwrap();
    assert!(ModelSpec::load(&path).is_ok());
    let _ = std::fs::remove_file(&path);
}

/// `OP_HEALTH` reports per-model replica liveness and queue state.
#[test]
fn health_op_reports_replicas() {
    let _g = guard();
    let (coord, _direct) = mlp_coord(BatchConfig::default(), 2);
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
    let health = client.health().unwrap();
    assert!(
        health.contains("bmlp v1 replicas 2/2"),
        "health must show the whole replica set, got: {health:?}"
    );
}

/// `OP_DRAIN` stops admission, keeps answering observation ops until
/// connections quiesce, and every serving thread exits.
#[test]
fn drain_op_quiesces_server() {
    let _g = guard();
    let (coord, direct) = mlp_coord(BatchConfig::default(), 1);
    let mut server =
        tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let addr = server.addr().to_string();
    let mut rng = Rng::new(9105);
    let img = image(&mut rng);
    let want = direct.predict(&tensor(&img)).unwrap();
    let mut client = tcp::Client::connect(&addr).unwrap();
    assert_eq!(client.predict("bmlp", &img).unwrap(), want);
    // a second client asks for the drain and gets the ack
    let mut ctl = tcp::Client::connect(&addr).unwrap();
    ctl.drain().unwrap();
    assert!(server.draining());
    // every serving thread exits once in-flight work is answered
    assert!(
        server.wait_idle(Duration::from_secs(10)),
        "drain must quiesce all serving threads"
    );
    // new connections are refused (listener closed) or answered with an
    // error frame and closed — either way no new work is admitted
    match tcp::Client::connect(&addr) {
        Err(_) => {}
        Ok(mut c) => assert!(c.predict("bmlp", &img).is_err()),
    }
    server.shutdown();
}

/// Soak: sustained concurrent traffic while panics and stalls fire
/// mid-run. Every request must be answered exactly once with a valid
/// status, successful scores stay bit-identical to the oracle, and once
/// the faults run dry the replica set is whole and serving again.
#[test]
fn chaos_soak_answers_everything_exactly_once() {
    let _g = guard();
    const CLIENTS: u64 = 4;
    const PER_CLIENT: usize = 100;
    let (coord, direct) = mlp_coord(
        BatchConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            request_timeout: Some(Duration::from_millis(500)),
        },
        2,
    );
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let addr = handle.addr().to_string();
    // faults land mid-soak: 3 panicking batches, 5 stalled batches
    fault::arm("panic-batch", 20, 3);
    fault::arm("slow-batch", 10, 5);
    let counts = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let addr = addr.clone();
            let direct = &direct;
            joins.push(s.spawn(move || {
                let mut client = tcp::Client::connect(&addr).unwrap();
                let mut rng = Rng::new(7000 + c);
                let (mut ok, mut errs, mut shed, mut busy) = (0usize, 0usize, 0usize, 0usize);
                for r in 0..PER_CLIENT {
                    let img = image(&mut rng);
                    match client.try_predict("bmlp", &img).unwrap() {
                        tcp::Reply::Scores(scores) => {
                            let want = direct.predict(&tensor(&img)).unwrap();
                            assert_eq!(scores, want, "conn {c} request {r} drifted");
                            ok += 1;
                        }
                        tcp::Reply::Err(_) => errs += 1,
                        tcp::Reply::DeadlineExceeded => shed += 1,
                        tcp::Reply::Overloaded => busy += 1,
                    }
                }
                (ok, errs, shed, busy)
            }));
        }
        joins
            .into_iter()
            .map(|j| j.join().unwrap())
            .fold((0, 0, 0, 0), |a, b| {
                (a.0 + b.0, a.1 + b.1, a.2 + b.2, a.3 + b.3)
            })
    });
    let (ok, errs, shed, busy) = counts;
    let total = (CLIENTS as usize) * PER_CLIENT;
    assert_eq!(ok + errs + shed + busy, total, "exactly one reply each");
    assert!(ok > 0, "some traffic must succeed");
    assert!(errs > 0, "the armed panics must surface as errors");
    assert_eq!(coord.metrics.panics("bmlp"), 3, "all three panics counted");
    // the faults are dry: the replica set must be whole and serving
    fault::disarm_all();
    let t0 = Instant::now();
    loop {
        let h = &coord.health()[0];
        if h.alive == h.replicas {
            break;
        }
        if t0.elapsed() > Duration::from_secs(10) {
            panic!("replica set not restored: {h:?}");
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut client = tcp::Client::connect(&addr).unwrap();
    let mut rng = Rng::new(9106);
    for _ in 0..20 {
        let img = image(&mut rng);
        let want = direct.predict(&tensor(&img)).unwrap();
        assert_eq!(client.predict("bmlp", &img).unwrap(), want);
    }
    let snap = coord.metrics.snapshot("bmlp").unwrap();
    assert!(
        snap.requests >= total as u64,
        "all soak requests accounted for: {}",
        snap.requests
    );
}
