//! Property suite for the non-sign activation representations: XNOR-Net
//! scaled binary (`ScaledSign` / `SBits`), ternary and 2-bit thermometer
//! planes. Three contracts are locked in:
//!
//! 1. **Plane-GEMM exactness** — a quantized input run through the packed
//!    per-plane kernels must reproduce the reference dot product over the
//!    dequantized values exactly (the symmetric-level combination is
//!    integer math, not an approximation).
//! 2. **Scale-epilogue fidelity** — the XNOR-Net `α·K`/`s` float
//!    epilogues must implement the scaling formula (and reduce to the
//!    true float convolution when the scale map is uniform and unpadded).
//! 3. **Dispatch/width invariance** — every `ESPRESSO_SIMD` level and
//!    both packing widths (u64/u32) produce identical scores, so the
//!    autotuned SIMD kernels carry over to the new representations.
//!
//! Plus the placement acceptance: `auto_place` must emit at least one
//! mixed Float/Binary placement whose plan routes a non-`Bits` packed
//! kind, over the sampled-spec distribution.

use espresso::alloc::Workspace;
use espresso::bitpack::simd;
use espresso::format::sample;
use espresso::layers::{Act, ActKind, Backend, ConvLayer, DenseLayer, Layer, OutRepr};
use espresso::net::{bmlp_spec, mnist_cnn_spec, retarget_repr, Network};
use espresso::tensor::{QuantTensor, ScaledBitTensor, Shape, Tensor};
use espresso::util::prop::check_simple;
use espresso::util::rng::Rng;

/// Random value on the exact level grid of a `planes`-plane quantizer.
fn grid_value(rng: &mut Rng, planes: usize, delta: f32) -> f32 {
    let levels: &[i32] = if planes == 2 { &[-1, 0, 1] } else { &[-3, -1, 1, 3] };
    delta * levels[rng.below(levels.len())] as f32
}

fn random_images(rng: &mut Rng, spec: &espresso::format::ModelSpec, n: usize) -> Vec<Tensor<u8>> {
    (0..n)
        .map(|_| {
            Tensor::from_vec(
                spec.input_shape,
                (0..spec.input_shape.len())
                    .map(|_| rng.next_u32() as u8)
                    .collect(),
            )
        })
        .collect()
}

/// Quantize→dequantize must be the identity on the level grid, and the
/// dequantized values must land exactly on `Δ·level`.
#[test]
fn quant_tensors_roundtrip_on_level_grids() {
    let mut rng = Rng::new(261);
    for planes in [2usize, 3] {
        for _ in 0..20 {
            let delta = rng.f32_range(0.25, 2.0);
            let s = Shape::new(3 + rng.below(4), 3 + rng.below(4), 1 + rng.below(3));
            let data: Vec<f32> = (0..s.len()).map(|_| grid_value(&mut rng, planes, delta)).collect();
            let t = Tensor::from_vec(s, data);
            let qt = QuantTensor::<u64>::from_tensor(&t, delta, planes);
            assert_eq!(qt.planes.len(), planes);
            assert_eq!(
                qt.kind(),
                if planes == 2 { ActKind::Ternary } else { ActKind::Bits2 }
            );
            let back = qt.to_tensor();
            assert_eq!(back.data, t.data, "planes={planes} delta={delta}");
        }
    }
}

/// Plane-GEMM exactness through a dense layer: ternary / 2-bit input
/// against a score layer (optionally α-scaled, with BN) must equal the
/// naive dot product over the dequantized input.
#[test]
fn prop_quant_dense_matches_dequantized_reference() {
    check_simple(
        "quant-dense-reference",
        40,
        262,
        |r| (r.next_u64(), 2 + r.below(2), 1 + r.below(3)),
        |&(seed, planes, batch)| {
            let mut rng = Rng::new(seed);
            let ws = Workspace::new();
            let (k, n) = (32 + rng.below(97), 8 + rng.below(25));
            let delta = rng.f32_range(0.25, 1.5);
            let w = rng.signs(n * k);
            let alpha: Option<Vec<f32>> = rng
                .bernoulli(0.5)
                .then(|| (0..n).map(|_| rng.f32_range(0.2, 1.8)).collect());
            let mut layer: DenseLayer<u64> = DenseLayer::new(k, n, &w, None, false);
            layer.configure_repr(OutRepr::Sign, 1.0, alpha.clone());
            let data: Vec<f32> = (0..batch * k)
                .map(|_| grid_value(&mut rng, planes, delta))
                .collect();
            let x = Tensor::from_vec(Shape { m: batch, n: k, l: 1 }, data.clone());
            let qt = QuantTensor::<u64>::from_tensor(&x, delta, planes);
            let got = layer
                .forward(Act::Quant(qt), Backend::Binary, &ws)
                .into_float();
            for b in 0..batch {
                for f in 0..n {
                    // integer level dot, scaled exactly as the kernel does
                    let dot: i64 = (0..k)
                        .map(|j| {
                            let lvl = (data[b * k + j] / delta).round() as i64;
                            let wj = if w[f * k + j] >= 0.0 { 1 } else { -1 };
                            lvl * wj
                        })
                        .sum();
                    let a = alpha.as_ref().map_or(1.0, |al| al[f]);
                    let want = dot as f32 * (delta * a);
                    let got_v = got.data[b * n + f];
                    if (got_v - want).abs() > 1e-3 * (1.0 + want.abs()) {
                        eprintln!("b={b} f={f}: got {got_v}, want {want}");
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Scaled-binary (XNOR-Net) dense epilogue: the score must be exactly
/// `s · α_f · Σ sign(x)·w` with `s` the per-sample input scale.
#[test]
fn prop_scaled_dense_matches_formula_reference() {
    check_simple(
        "scaled-dense-reference",
        40,
        263,
        |r| (r.next_u64(), 1 + r.below(4)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let ws = Workspace::new();
            let (k, n) = (24 + rng.below(105), 6 + rng.below(27));
            let w = rng.signs(n * k);
            let alpha: Vec<f32> = (0..n).map(|_| rng.f32_range(0.2, 1.8)).collect();
            let mut layer: DenseLayer<u64> = DenseLayer::new(k, n, &w, None, false);
            layer.configure_repr(OutRepr::Sign, 1.0, Some(alpha.clone()));
            let data: Vec<f32> = (0..batch * k)
                .map(|_| rng.f32_range(0.1, 2.0) * rng.sign())
                .collect();
            let x = Tensor::from_vec(Shape { m: batch, n: k, l: 1 }, data.clone());
            let st = ScaledBitTensor::<u64>::from_tensor(&x);
            assert_eq!(st.scale.len(), batch, "one scale group per row");
            let got = layer
                .forward(Act::Scaled(st), Backend::Binary, &ws)
                .into_float();
            for b in 0..batch {
                let row = &data[b * k..(b + 1) * k];
                let s = row.iter().map(|v| v.abs()).sum::<f32>() / k as f32;
                for f in 0..n {
                    let acc: i32 = (0..k)
                        .map(|j| {
                            let xb = if row[j] >= 0.0 { 1 } else { -1 };
                            let wj = if w[f * k + j] >= 0.0 { 1 } else { -1 };
                            xb * wj
                        })
                        .sum();
                    let want = acc as f32 * (s * alpha[f]);
                    let got_v = got.data[b * n + f];
                    if (got_v - want).abs() > 1e-3 * (1.0 + want.abs()) {
                        eprintln!("b={b} f={f}: got {got_v}, want {want}");
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// XNOR-Net conv K path, exact case: with a *uniform* scale map and no
/// padding, `α·K·acc` is not an approximation — it must match the true
/// float convolution of the ±A input.
#[test]
fn xnor_conv_uniform_scale_matches_float_conv() {
    let mut rng = Rng::new(264);
    let ws = Workspace::new();
    for trial in 0..8 {
        let (c, f) = (2 + rng.below(3), 4 + rng.below(9));
        let s = Shape::new(6 + rng.below(3), 6 + rng.below(3), c);
        let (kh, kw) = (1 + rng.below(3), 1 + rng.below(3));
        let a = rng.f32_range(0.3, 2.0);
        let alpha: Vec<f32> = (0..f).map(|_| rng.f32_range(0.2, 1.8)).collect();
        let mut layer: ConvLayer<u64> =
            ConvLayer::new(c, f, kh, kw, 1, 0, &rng.signs(f * kh * kw * c), None, false, None);
        layer.configure_repr(OutRepr::Sign, 1.0, Some(alpha));
        layer.prepare(s);
        let data: Vec<f32> = (0..s.len()).map(|_| a * rng.sign()).collect();
        let x = Tensor::from_vec(s, data);
        let st = ScaledBitTensor::<u64>::from_tensor(&x);
        assert!(st.scale.iter().all(|&v| (v - a).abs() < 1e-6));
        let binary = layer
            .forward(Act::Scaled(st), Backend::Binary, &ws)
            .into_float();
        let float = layer
            .forward(Act::Float(x), Backend::Float, &ws)
            .into_float();
        assert_eq!(binary.data.len(), float.data.len());
        for (i, (b, fl)) in binary.data.iter().zip(&float.data).enumerate() {
            assert!(
                (b - fl).abs() < 1e-3 * (1.0 + fl.abs()),
                "trial {trial} elem {i}: binary {b} vs float {fl}"
            );
        }
    }
}

/// XNOR-Net conv K path, general case: random scale maps with zero
/// padding. The kernel must implement the formula `y = α_f · K_p · acc`
/// with `K_p` the window mean of in-bounds per-pixel scales — checked
/// against a from-first-principles reference.
#[test]
fn prop_xnor_conv_matches_k_formula_reference() {
    check_simple(
        "xnor-conv-k-reference",
        24,
        265,
        |r| r.next_u64(),
        |&seed| {
            let mut rng = Rng::new(seed);
            let ws = Workspace::new();
            let (c, f) = (2 + rng.below(3), 4 + rng.below(7));
            let s = Shape::new(5 + rng.below(4), 5 + rng.below(4), c);
            let (kh, kw) = (2 + rng.below(2), 2 + rng.below(2));
            let pad = rng.below(2);
            let stride = 1 + rng.below(2);
            let w = rng.signs(f * kh * kw * c);
            let alpha: Vec<f32> = (0..f).map(|_| rng.f32_range(0.2, 1.8)).collect();
            let mut layer: ConvLayer<u64> =
                ConvLayer::new(c, f, kh, kw, stride, pad, &w, None, false, None);
            layer.configure_repr(OutRepr::Sign, 1.0, Some(alpha.clone()));
            let out_shape = layer.prepare(s);
            let data: Vec<f32> = (0..s.len())
                .map(|_| rng.f32_range(0.1, 2.0) * rng.sign())
                .collect();
            let x = Tensor::from_vec(s, data.clone());
            let got = layer
                .forward(Act::Scaled(ScaledBitTensor::<u64>::from_tensor(&x)), Backend::Binary, &ws)
                .into_float();
            // per-pixel A map (mean |x| over channels)
            let a_map: Vec<f32> = data
                .chunks(c)
                .map(|px| px.iter().map(|v| v.abs()).sum::<f32>() / c as f32)
                .collect();
            let (oh, ow) = (out_shape.m, out_shape.n);
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut k_sum = 0.0f32;
                    let mut accs = vec![0i32; f];
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy as usize >= s.m {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix as usize >= s.n {
                                continue;
                            }
                            let px = iy as usize * s.n + ix as usize;
                            k_sum += a_map[px];
                            for fi in 0..f {
                                for ch in 0..c {
                                    let xv = if data[px * c + ch] >= 0.0 { 1 } else { -1 };
                                    let wv = if w[((fi * kh + ky) * kw + kx) * c + ch] >= 0.0 {
                                        1
                                    } else {
                                        -1
                                    };
                                    accs[fi] += xv * wv;
                                }
                            }
                        }
                    }
                    let kp = k_sum / (kh * kw) as f32;
                    for fi in 0..f {
                        let want = accs[fi] as f32 * (alpha[fi] * kp);
                        let got_v = got.data[(oy * ow + ox) * f + fi];
                        if (got_v - want).abs() > 1e-3 * (1.0 + want.abs()) {
                            eprintln!("({oy},{ox}) f={fi}: got {got_v}, want {want}");
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

/// Float- and binary-backend quantized tails agree away from threshold
/// boundaries: the integer-domain threshold pack must binarize each
/// feature exactly like float BN + level comparison.
#[test]
fn prop_quant_tail_matches_float_backend_off_boundary() {
    check_simple(
        "quant-tail-float-binary",
        30,
        266,
        |r| (r.next_u64(), if r.bernoulli(0.5) { OutRepr::Ternary } else { OutRepr::Quant2 }),
        |&(seed, repr)| {
            let mut rng = Rng::new(seed);
            let ws = Workspace::new();
            let (k, n) = (48 + rng.below(81), 8 + rng.below(17));
            let delta = rng.f32_range(0.5, 1.5);
            let bn = make_bn(&mut rng, n);
            let w = rng.signs(n * k);
            let mut layer: DenseLayer<u64> = DenseLayer::new(k, n, &w, Some(bn.clone()), true);
            layer.configure_repr(repr, delta, None);
            let x = Tensor::from_vec(Shape::vector(k), rng.signs(k));
            let b_out = layer
                .forward(Act::Float(x.clone()), Backend::Binary, &ws)
                .into_float();
            let f_out = layer
                .forward(Act::Float(x.clone()), Backend::Float, &ws)
                .into_float();
            // recompute BN(y) to find features sitting on a level boundary
            let mut y: Vec<f32> = (0..n)
                .map(|f| (0..k).map(|j| x.data[j] * w[f * k + j]).sum())
                .collect();
            bn.apply(&mut y);
            for f in 0..n {
                let near_boundary = repr
                    .level_thresholds()
                    .iter()
                    .any(|&t| (y[f] - delta * t).abs() < 1e-2);
                if near_boundary {
                    continue;
                }
                if b_out.data[f] != f_out.data[f] {
                    eprintln!("feature {f}: binary {} vs float {}", b_out.data[f], f_out.data[f]);
                    return false;
                }
            }
            true
        },
    );
}

/// Well-conditioned random BN parameters (γ bounded away from 0).
fn make_bn(rng: &mut Rng, f: usize) -> espresso::layers::BnParams {
    espresso::layers::BnParams {
        eps: 1e-4,
        gamma: (0..f)
            .map(|_| rng.f32_range(0.2, 2.0) * rng.sign())
            .collect(),
        beta: (0..f).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
        mean: (0..f).map(|_| rng.f32_range(-3.0, 3.0)).collect(),
        var: (0..f).map(|_| rng.f32_range(0.3, 4.0)).collect(),
    }
}

/// Every available `ESPRESSO_SIMD` dispatch level must produce identical
/// scores on networks using each output representation (the scaled /
/// multi-bit tails ride the same popcount kernels).
#[test]
fn simd_dispatch_levels_agree_on_all_representations() {
    let mut rng = Rng::new(267);
    let levels: Vec<u8> = [
        simd::LEVEL_SCALAR,
        simd::LEVEL_AVX2,
        simd::LEVEL_AVX512,
        simd::LEVEL_NEON,
    ]
    .into_iter()
    .filter(|&l| simd::level_available(l))
    .collect();
    assert!(!levels.is_empty());
    for (repr, delta, with_alpha) in [
        (OutRepr::Sign, 1.0, false),
        (OutRepr::ScaledSign, 1.0, true),
        (OutRepr::Quant2, 0.75, true),
        (OutRepr::Ternary, 1.25, false),
    ] {
        let mut spec = mnist_cnn_spec(&mut rng, 0.25);
        retarget_repr(&mut spec, &mut rng, repr, delta, with_alpha);
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let imgs = random_images(&mut rng, &spec, 2);
        let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
        let mut baseline: Option<Vec<Vec<f32>>> = None;
        for &l in &levels {
            simd::force_level(l);
            let got = net.predict_batch_bytes(&refs);
            match &baseline {
                None => baseline = Some(got),
                Some(want) => assert_eq!(
                    &got,
                    want,
                    "repr {repr} diverges at level {}",
                    simd::level_name(l)
                ),
            }
        }
    }
    simd::force_level(0); // back to auto-detect
}

/// u32 and u64 packing must agree exactly on every representation —
/// the A4 width comparison measures identical code, scaled paths
/// included.
#[test]
fn u32_and_u64_agree_on_all_representations() {
    let mut rng = Rng::new(268);
    for (repr, delta, with_alpha) in [
        (OutRepr::ScaledSign, 1.0, true),
        (OutRepr::Quant2, 0.5, false),
        (OutRepr::Ternary, 1.5, true),
    ] {
        for cnn in [false, true] {
            let mut spec = if cnn {
                mnist_cnn_spec(&mut rng, 0.25)
            } else {
                bmlp_spec(&mut rng, 96, 2)
            };
            retarget_repr(&mut spec, &mut rng, repr, delta, with_alpha);
            let n64 = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let n32 = Network::<u32>::from_spec(&spec, Backend::Binary).unwrap();
            for img in random_images(&mut rng, &spec, 2) {
                assert_eq!(
                    n64.predict_bytes(&img),
                    n32.predict_bytes(&img),
                    "{} ({repr})",
                    spec.name
                );
            }
        }
    }
}

/// Retargeted networks stay plan≡layerwalk bit-identical under hybrid
/// placements (the generic suite draws reprs randomly; this pins every
/// repr explicitly, batched and single).
#[test]
fn prop_retargeted_plan_equals_layerwalk() {
    check_simple(
        "retargeted-plan-layerwalk",
        16,
        269,
        |r| {
            let reprs = [OutRepr::ScaledSign, OutRepr::Quant2, OutRepr::Ternary];
            (r.next_u64(), reprs[r.below(3)], 1 + r.below(3))
        },
        |&(seed, repr, batch)| {
            let mut rng = Rng::new(seed);
            let mut spec = sample::sample(&mut rng);
            let delta = rng.f32_range(0.5, 1.5);
            let with_alpha = rng.bernoulli(0.5);
            retarget_repr(&mut spec, &mut rng, repr, delta, with_alpha);
            let imgs = random_images(&mut rng, &spec, batch);
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let mut net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
            let placement: Vec<Backend> = (0..net.layer_count())
                .map(|_| {
                    if rng.bernoulli(0.7) {
                        Backend::Binary
                    } else {
                        Backend::Float
                    }
                })
                .collect();
            net.set_backends(&placement);
            let batched = net.predict_batch_bytes(&refs);
            imgs.iter().zip(&batched).all(|(img, got)| {
                let walk = net
                    .forward_layerwalk(Act::Bytes(img.clone()))
                    .into_float()
                    .data;
                net.predict_bytes(img) == walk && *got == walk
            })
        },
    );
}

/// Acceptance: over the sampled-spec distribution, `auto_place` emits at
/// least one *mixed* Float/Binary placement whose plan carries a
/// non-`Bits` packed kind — and that plan still predicts correctly.
#[test]
fn auto_place_emits_mixed_placement_with_new_kind() {
    let mut found = false;
    for seed in 0..400u64 {
        let mut rng = Rng::new(seed);
        let spec = sample::sample(&mut rng);
        let mut net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let placed = net.auto_place().to_vec();
        let mixed = placed.contains(&Backend::Float) && placed.contains(&Backend::Binary);
        let new_kind = net.plan().steps.iter().any(|s| {
            matches!(
                s.out_kind,
                ActKind::ScaledBits | ActKind::Bits2 | ActKind::Ternary
            ) || matches!(
                s.in_kind,
                ActKind::ScaledBits | ActKind::Bits2 | ActKind::Ternary
            )
        });
        if mixed && new_kind {
            // the placement must still predict (plan≡layerwalk)
            let img = &random_images(&mut rng, &spec, 1)[0];
            let walk = net
                .forward_layerwalk(Act::Bytes(img.clone()))
                .into_float()
                .data;
            assert_eq!(net.predict_bytes(img), walk, "seed {seed}");
            found = true;
            break;
        }
    }
    assert!(
        found,
        "no sampled spec produced a mixed placement routing a new kind"
    );
}

/// The plan and profile tables surface the per-step scale mode.
#[test]
fn plan_render_shows_representation_and_scale_mode() {
    let mut rng = Rng::new(270);
    let mut spec = mnist_cnn_spec(&mut rng, 0.25);
    retarget_repr(&mut spec, &mut rng, OutRepr::Ternary, 0.75, true);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let table = net.plan().render();
    assert!(table.contains("scale"), "{table}");
    assert!(table.contains("Tern"), "{table}");
    // retargeted hidden conv: α weight scales + a quantized output step
    assert!(table.contains("a+d'"), "{table}");
    let img = &random_images(&mut rng, &spec, 1)[0];
    let _ = net.predict_bytes(img);
    let prof = net.profile().render();
    assert!(prof.contains("scale"), "{prof}");
}
