//! The batch-equivalence property suite — the contract that locks in the
//! batched CNN forward path: for ANY architecture and ANY batch, the
//! batched forward must be **bit-identical** to independent single-image
//! forwards, on both the binary and the float backends.
//!
//! This holds exactly (not approximately) because every kernel keeps
//! per-row accumulation order: the batched GEMM computes each output row
//! with the same dot-product sweep the single-image call uses, pooling
//! and thresholds run on per-image blocks, and the zero-padding
//! correction is applied per image. Any refactor of the batch plumbing
//! that breaks block addressing fails this suite immediately.

use espresso::format::sample;
use espresso::layers::Backend;
use espresso::net::Network;
use espresso::tensor::Tensor;
use espresso::util::prop::check_simple;
use espresso::util::rng::Rng;

/// Core property: batched == per-image, both backends, both word widths'
/// default (u64). Inputs are (spec seed, batch size).
#[test]
fn prop_batched_forward_is_bit_identical_to_singles() {
    check_simple(
        "batched-forward-equals-singles",
        24,
        211,
        |r| (r.next_u64(), 2 + r.below(4)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample(&mut rng);
            let imgs: Vec<Tensor<u8>> = (0..batch)
                .map(|_| {
                    Tensor::from_vec(
                        spec.input_shape,
                        (0..spec.input_shape.len())
                            .map(|_| rng.next_u32() as u8)
                            .collect(),
                    )
                })
                .collect();
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            for backend in [Backend::Binary, Backend::Float] {
                let net = Network::<u64>::from_spec(&spec, backend).unwrap();
                let batched = net.predict_batch_bytes(&refs);
                if batched.len() != batch {
                    return false;
                }
                for (img, got) in imgs.iter().zip(&batched) {
                    // bit-identical: f32 == comparison, no tolerance
                    if *got != net.predict_bytes(img) {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// CNN-only variant at a fixed larger batch, exercising deeper stacks
/// (conv→conv→dense) where block addressing errors would compound.
#[test]
fn prop_batched_cnn_forward_is_bit_identical() {
    check_simple(
        "batched-cnn-equals-singles",
        16,
        212,
        |r| (r.next_u64(), 2 + r.below(5)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample_cnn(&mut rng);
            let imgs: Vec<Tensor<u8>> = (0..batch)
                .map(|_| {
                    Tensor::from_vec(
                        spec.input_shape,
                        (0..spec.input_shape.len())
                            .map(|_| rng.next_u32() as u8)
                            .collect(),
                    )
                })
                .collect();
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            for backend in [Backend::Binary, Backend::Float] {
                let net = Network::<u64>::from_spec(&spec, backend).unwrap();
                let batched = net.predict_batch_bytes(&refs);
                for (img, got) in imgs.iter().zip(&batched) {
                    if *got != net.predict_bytes(img) {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// u32 packing must satisfy the same equivalence (the A4 width
/// comparison measures identical code paths, so both must batch right).
#[test]
fn prop_batched_forward_u32_words() {
    check_simple(
        "batched-forward-u32",
        10,
        213,
        |r| (r.next_u64(), 2 + r.below(3)),
        |&(seed, batch)| {
            let mut rng = Rng::new(seed);
            let spec = sample::sample_cnn(&mut rng);
            let imgs: Vec<Tensor<u8>> = (0..batch)
                .map(|_| {
                    Tensor::from_vec(
                        spec.input_shape,
                        (0..spec.input_shape.len())
                            .map(|_| rng.next_u32() as u8)
                            .collect(),
                    )
                })
                .collect();
            let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
            let net = Network::<u32>::from_spec(&spec, Backend::Binary).unwrap();
            let batched = net.predict_batch_bytes(&refs);
            imgs.iter()
                .zip(&batched)
                .all(|(img, got)| *got == net.predict_bytes(img))
        },
    );
}

/// The paper's evaluation CNN (scaled down) through the engine-level
/// batched path: deeper pipeline, pad=1 "same" convs, pooling stages.
#[test]
fn bcnn_batched_forward_matches_singles() {
    let mut rng = Rng::new(214);
    let spec = espresso::net::bcnn_spec(&mut rng, 0.125);
    let imgs: Vec<Tensor<u8>> = (0..4)
        .map(|_| {
            Tensor::from_vec(
                spec.input_shape,
                (0..spec.input_shape.len())
                    .map(|_| rng.next_u32() as u8)
                    .collect(),
            )
        })
        .collect();
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    for backend in [Backend::Binary, Backend::Float] {
        let net = Network::<u64>::from_spec(&spec, backend).unwrap();
        let batched = net.predict_batch_bytes(&refs);
        for (i, (img, got)) in imgs.iter().zip(&batched).enumerate() {
            assert_eq!(*got, net.predict_bytes(img), "{backend:?} image {i}");
        }
    }
}
