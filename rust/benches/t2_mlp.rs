//! **T2 — Table 2: binary MLP on MNIST, batch 1.**
//!
//! Paper (GTX 960): BinaryNet 18 ms | Nervana/neon 17 ms | Espresso CPU
//! 37.4 ms | GPU 3.2 ms (5.6×) | GPU^opt 0.26 ms (68×). Memory (M1):
//! 140.6 MB float → 4.57 MB packed (≈31×).
//!
//! Engines measured here, on the same 784-4096-4096-4096-10 network:
//! the two baseline re-implementations (pack-per-forward), the native
//! float comparator ("CPU"), the XLA float engine ("GPU" analogue — an
//! independently optimized dense stack; needs `make artifacts-full`),
//! the XLA *binary* engine (Pallas packed GEMM via PJRT), and the native
//! binary-optimized engine ("GPU^opt" analogue).

use espresso::baseline::{BaselineEngine, BaselineKind};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::{artifact_exists, Engine, NativeEngine, XlaEngine, XlaModelKind};
use espresso::tensor::{Shape, Tensor};
use espresso::util::bench::{bench, BenchConfig, BenchTable};
use espresso::util::rng::Rng;
use std::path::Path;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let (hidden, layers) = if quick { (1024, 3) } else { (4096, 3) };
    println!("== T2: BMLP 784-{hidden}x{layers}-10, batch 1 (paper Table 2) ==");
    let mut rng = Rng::new(2);
    let spec = bmlp_spec(&mut rng, hidden, layers);
    let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
    let img = Tensor::from_vec(Shape::vector(784), img);

    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: if quick { 3 } else { 10 },
        max_iters: if quick { 5 } else { 60 },
        measure_time: std::time::Duration::from_secs(if quick { 2 } else { 10 }),
    };
    // the slow baselines get fewer iterations
    let slow_cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: if quick { 3 } else { 8 },
        measure_time: std::time::Duration::from_secs(if quick { 3 } else { 15 }),
    };

    let mut table = BenchTable::new("T2 BMLP batch-1 prediction").baseline("binarynet (pack per forward)");

    let bnet = BaselineEngine::from_spec(&spec, BaselineKind::BinaryNet).unwrap();
    table.push(bench("binarynet (pack per forward)", &slow_cfg, || {
        let _ = bnet.predict(&img).unwrap();
    }));
    let neon = BaselineEngine::from_spec(&spec, BaselineKind::NeonLike).unwrap();
    table.push(bench("neon-like (pack per forward)", &slow_cfg, || {
        let _ = neon.predict(&img).unwrap();
    }));

    let float = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Float).unwrap(),
        "float",
    );
    table.push(bench("espresso float (CPU comparator)", &cfg, || {
        let _ = float.predict(&img).unwrap();
    }));

    // XLA engines need the paper-size artifacts (make artifacts-full)
    let dir = Path::new("artifacts");
    if !quick && artifact_exists(dir, "bmlp_float") {
        match XlaEngine::load(dir, "bmlp_float", &spec, XlaModelKind::MlpFloat) {
            Ok(e) => table.push(bench("espresso xla-float (accel analogue)", &cfg, || {
                let _ = e.predict(&img).unwrap();
            })),
            Err(err) => println!("  (xla-float skipped: {err})"),
        }
    } else {
        println!("  (xla rows need `make artifacts-full`)");
    }
    if !quick && artifact_exists(dir, "bmlp_binary") {
        match XlaEngine::load(dir, "bmlp_binary", &spec, XlaModelKind::MlpBinary) {
            Ok(e) => table.push(bench("espresso xla-binary (pallas packed)", &cfg, || {
                let _ = e.predict(&img).unwrap();
            })),
            Err(err) => println!("  (xla-binary skipped: {err})"),
        }
    }

    let opt = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
        "opt",
    );
    table.push(bench("espresso opt (binary, prepacked)", &cfg, || {
        let _ = opt.predict(&img).unwrap();
    }));

    println!("{}", table.render());
    println!("paper: BinaryNet 18ms | neon 17ms | CPU 37.4ms | GPU 3.2ms (5.6x) | GPU^opt 0.26ms (68x)");

    // M1: memory report
    let rep = opt.net.memory_report();
    println!(
        "\nM1 memory: float {:.2} MB -> packed {:.2} MB ({:.1}x; paper: 140.6 -> 4.57 MB, ~31x)",
        rep.total_float() as f64 / 1e6,
        rep.total_packed() as f64 / 1e6,
        rep.saving()
    );

    let dirp = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dirp);
    let _ = std::fs::write(dirp.join("t2_mlp.tsv"), table.tsv());
}
