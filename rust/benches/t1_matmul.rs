//! **T1 — Table 1: binary dense matrix multiplication.**
//!
//! Paper (GTX 960, 8192×8192): BinaryNet 88 ms | Espresso GPU^opt-32
//! 16 ms (5.5×) | GPU^opt-64 11 ms (8×).
//!
//! This harness reproduces the comparison structure on the CPU substrate:
//! a faithful BinaryNet-style baseline (binarize + pack *both* operands
//! on every call, strided column packing, unblocked kernel) against the
//! Espresso path (pre-packed operands, register-blocked kernel) at both
//! packing widths (experiment **A4**), plus the float GEMM for context.
//!
//! Default size 4096 (single-core testbed; the paper's 8192 float row
//! would run for minutes); ESPRESSO_BENCH_QUICK=1 drops to 1024.

use espresso::baseline;
use espresso::bitpack::{self, pack_matrix_cols, pack_matrix_rows, simd, words_for};
use espresso::linalg;
use espresso::util::bench::{bench_throughput, BenchConfig, BenchTable};
use espresso::util::rng::Rng;
use espresso::util::tune::{self, Family, KernelChoice, MicroKernel};

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let n: usize = std::env::var("ESPRESSO_T1_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 1024 } else { 4096 });
    let ops = 2.0 * (n as f64).powi(3); // effective multiply-adds x2

    println!("== T1: binary matmul {n}x{n} (paper Table 1 @8192: BinaryNet 88ms, esp32 16ms, esp64 11ms) ==");
    let mut rng = Rng::new(1);
    let a = rng.signs(n * n);
    let b = rng.signs(n * n);
    // transposed copy for the baseline's column-packing path
    let mut b_t = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            b_t[j * n + i] = b[i * n + j];
        }
    }
    let pa64 = pack_matrix_rows::<u64>(&a, n, n);
    let pb64 = pack_matrix_rows::<u64>(&b, n, n);
    let pa32 = pack_matrix_rows::<u32>(&a, n, n);
    let pb32 = pack_matrix_rows::<u32>(&b, n, n);

    // autotune both packing widths up front so the espresso rows below run
    // the registry's chosen micro-kernel (ESPRESSO_TUNE=off pins defaults)
    tune::tune_gemm::<u64>(Family::Binary, n, n, words_for::<u64>(n));
    tune::tune_gemm::<u32>(Family::Binary, n, n, words_for::<u32>(n));

    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: if quick { 3 } else { 8 },
        measure_time: std::time::Duration::from_secs(if quick { 3 } else { 20 }),
    };
    let mut out = vec![0i32; n * n];
    let mut table = BenchTable::new(&format!("T1 binary matmul {n}^3")).baseline("binarynet-style (pack/call, unblocked)");

    // BinaryNet-style: pack activations by rows AND weights by columns on
    // every call, then an unblocked kernel (paper §6.2's measured flaws)
    table.push(bench_throughput(
        "binarynet-style (pack/call, unblocked)",
        &cfg,
        ops,
        "op",
        || {
            let pa = pack_matrix_rows::<u64>(&a, n, n);
            let pb = pack_matrix_cols::<u64>(&b_t, n, n);
            baseline::bench_naive_gemm(&pa, &pb, &mut out, n, n, n);
        },
    ));

    // Espresso: operands pre-packed once at load; blocked kernel
    table.push(bench_throughput(
        "espresso 32-bit (prepacked, blocked)",
        &cfg,
        ops,
        "op",
        || bitpack::gemm_into::<u32>(&pa32, &pb32, &mut out, n, n, n),
    ));
    table.push(bench_throughput(
        "espresso 64-bit (prepacked, blocked)",
        &cfg,
        ops,
        "op",
        || bitpack::gemm_into::<u64>(&pa64, &pb64, &mut out, n, n, n),
    ));

    // float context row (smaller iteration budget; it is slow by design)
    let float_cfg = BenchConfig {
        warmup_iters: 0,
        min_iters: if quick { 1 } else { 2 },
        max_iters: if quick { 1 } else { 2 },
        measure_time: std::time::Duration::from_secs(1),
    };
    let mut fout = vec![0f32; n * n];
    table.push(bench_throughput(
        "float sgemm (context)",
        &float_cfg,
        ops,
        "flop",
        || linalg::sgemm_into(&a, &b, &mut fout, n, n, n),
    ));

    println!("{}", table.render());
    println!("paper speedups over BinaryNet: 5.5x (32-bit), 8x (64-bit); A4 64-vs-32 ~= 1.25x");
    save_tsv("t1_matmul", &table);

    kernel_section(n, &pa64, &pb64, &mut out, quick, &table);
}

/// T1-K: the 64-bit binary GEMM under each fixed micro-kernel shape (at
/// the static default tile/grain) vs the autotuner's pick. Because the
/// tuner's candidate 0 is the exact static default and ties go to the
/// earliest candidate, the tuned row can never lose to the legacy config
/// by more than timing noise. Records every variant in `BENCH_t1.json`.
fn kernel_section(
    n: usize,
    pa: &[u64],
    pb: &[u64],
    out: &mut [i32],
    quick: bool,
    main: &BenchTable,
) {
    let kw = words_for::<u64>(n);
    let ops = 2.0 * (n as f64).powi(3);
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 2,
        max_iters: if quick { 2 } else { 4 },
        measure_time: std::time::Duration::from_secs(if quick { 2 } else { 8 }),
    };
    let simd_name = simd::level_name(simd::level());
    let default = tune::default_for(Family::Binary, 64, n, kw);
    let tuned = tune::lookup(Family::Binary, 64, n, kw);
    println!("\n== T1-K: micro-kernel variants, 64-bit {n}x{n} (simd {simd_name}) ==");
    let mut ktable = BenchTable::new("T1-K kernel variants").baseline("fixed-1x8 (default)");
    let variants = [
        ("fixed-1x4", KernelChoice { micro: MicroKernel::Mk1x4, ..default }),
        ("fixed-1x8 (default)", default),
        ("fixed-2x4", KernelChoice { micro: MicroKernel::Mk2x4, ..default }),
        ("tuned", tuned),
    ];
    for (label, choice) in variants {
        ktable.push(bench_throughput(label, &cfg, ops, "op", || {
            bitpack::gemm::gemm_words_with_choice::<u64>(pa, pb, out, n, n, kw, n, choice);
        }));
    }
    println!("{}", ktable.render());
    let best_fixed = ktable.rows[..3]
        .iter()
        .map(|r| r.mean_ns())
        .fold(f64::INFINITY, f64::min);
    let tuned_ns = ktable.rows[3].mean_ns();
    println!(
        "tuned pick {tuned} vs best fixed: {:.2}x (>= ~1.0 expected; default is tuner candidate 0)",
        best_fixed / tuned_ns
    );

    let k32 = tune::lookup(Family::Binary, 32, n, words_for::<u32>(n));
    let mut jrows = Vec::new();
    for r in &main.rows {
        let kc = if r.name.starts_with("espresso 32") {
            Some(k32)
        } else if r.name.starts_with("espresso 64") {
            Some(tuned)
        } else {
            None
        };
        jrows.push(format!(
            "    {{\"name\": \"{}\", \"mean_ns\": {:.0}, \"simd_level\": \"{simd_name}\", \
             \"kernel\": \"{}\", \"tile_rows\": {}}}",
            r.name,
            r.mean_ns(),
            kc.map_or_else(|| "-".to_string(), |c| c.to_string()),
            kc.map_or(0, |c| c.tile_rows),
        ));
    }
    let mut jvars = Vec::new();
    for (i, (label, choice)) in variants.iter().enumerate() {
        jvars.push(format!(
            "    {{\"variant\": \"{label}\", \"kernel\": \"{choice}\", \"tile_rows\": {}, \
             \"grain\": {}, \"mean_ns\": {:.0}}}",
            choice.tile_rows,
            choice.grain,
            ktable.rows[i].mean_ns(),
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"t1_matmul\",\n  \"n\": {n},\n  \"simd_level\": \"{simd_name}\",\n  \
         \"tuned_kernel\": \"{tuned}\",\n  \"tuned_vs_best_fixed\": {:.3},\n  \"rows\": [\n{}\n  ],\n  \
         \"kernel_variants\": [\n{}\n  ]\n}}\n",
        best_fixed / tuned_ns,
        jrows.join(",\n"),
        jvars.join(",\n"),
    );
    // package root and workspace root (whichever the driver inspects)
    let _ = std::fs::write("BENCH_t1.json", &json);
    let _ = std::fs::write("../BENCH_t1.json", &json);
}

fn save_tsv(name: &str, table: &BenchTable) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(format!("{name}.tsv")), table.tsv());
}
