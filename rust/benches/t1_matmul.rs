//! **T1 — Table 1: binary dense matrix multiplication.**
//!
//! Paper (GTX 960, 8192×8192): BinaryNet 88 ms | Espresso GPU^opt-32
//! 16 ms (5.5×) | GPU^opt-64 11 ms (8×).
//!
//! This harness reproduces the comparison structure on the CPU substrate:
//! a faithful BinaryNet-style baseline (binarize + pack *both* operands
//! on every call, strided column packing, unblocked kernel) against the
//! Espresso path (pre-packed operands, register-blocked kernel) at both
//! packing widths (experiment **A4**), plus the float GEMM for context.
//!
//! Default size 4096 (single-core testbed; the paper's 8192 float row
//! would run for minutes); ESPRESSO_BENCH_QUICK=1 drops to 1024.

use espresso::baseline;
use espresso::bitpack::{self, pack_matrix_cols, pack_matrix_rows};
use espresso::linalg;
use espresso::util::bench::{bench_throughput, BenchConfig, BenchTable};
use espresso::util::rng::Rng;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let n: usize = std::env::var("ESPRESSO_T1_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 1024 } else { 4096 });
    let ops = 2.0 * (n as f64).powi(3); // effective multiply-adds x2

    println!("== T1: binary matmul {n}x{n} (paper Table 1 @8192: BinaryNet 88ms, esp32 16ms, esp64 11ms) ==");
    let mut rng = Rng::new(1);
    let a = rng.signs(n * n);
    let b = rng.signs(n * n);
    // transposed copy for the baseline's column-packing path
    let mut b_t = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            b_t[j * n + i] = b[i * n + j];
        }
    }
    let pa64 = pack_matrix_rows::<u64>(&a, n, n);
    let pb64 = pack_matrix_rows::<u64>(&b, n, n);
    let pa32 = pack_matrix_rows::<u32>(&a, n, n);
    let pb32 = pack_matrix_rows::<u32>(&b, n, n);

    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: if quick { 3 } else { 8 },
        measure_time: std::time::Duration::from_secs(if quick { 3 } else { 20 }),
    };
    let mut out = vec![0i32; n * n];
    let mut table = BenchTable::new(&format!("T1 binary matmul {n}^3")).baseline("binarynet-style (pack/call, unblocked)");

    // BinaryNet-style: pack activations by rows AND weights by columns on
    // every call, then an unblocked kernel (paper §6.2's measured flaws)
    table.push(bench_throughput(
        "binarynet-style (pack/call, unblocked)",
        &cfg,
        ops,
        "op",
        || {
            let pa = pack_matrix_rows::<u64>(&a, n, n);
            let pb = pack_matrix_cols::<u64>(&b_t, n, n);
            baseline::bench_naive_gemm(&pa, &pb, &mut out, n, n, n);
        },
    ));

    // Espresso: operands pre-packed once at load; blocked kernel
    table.push(bench_throughput(
        "espresso 32-bit (prepacked, blocked)",
        &cfg,
        ops,
        "op",
        || bitpack::gemm_into::<u32>(&pa32, &pb32, &mut out, n, n, n),
    ));
    table.push(bench_throughput(
        "espresso 64-bit (prepacked, blocked)",
        &cfg,
        ops,
        "op",
        || bitpack::gemm_into::<u64>(&pa64, &pb64, &mut out, n, n, n),
    ));

    // float context row (smaller iteration budget; it is slow by design)
    let float_cfg = BenchConfig {
        warmup_iters: 0,
        min_iters: if quick { 1 } else { 2 },
        max_iters: if quick { 1 } else { 2 },
        measure_time: std::time::Duration::from_secs(1),
    };
    let mut fout = vec![0f32; n * n];
    table.push(bench_throughput(
        "float sgemm (context)",
        &float_cfg,
        ops,
        "flop",
        || linalg::sgemm_into(&a, &b, &mut fout, n, n, n),
    ));

    println!("{}", table.render());
    println!("paper speedups over BinaryNet: 5.5x (32-bit), 8x (64-bit); A4 64-vs-32 ~= 1.25x");
    save_tsv("t1_matmul", &table);
}

fn save_tsv(name: &str, table: &BenchTable) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(format!("{name}.tsv")), table.tsv());
}
