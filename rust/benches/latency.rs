//! **Latency — single-image (B=1) forward latency, spawn-per-call vs
//! persistent pool (ISSUE 5).**
//!
//! The paper's speedup story is batch-1 inference latency; every µs the
//! execution layer adds around the bit-packed GEMMs lands directly on
//! p50. This bench measures the MNIST-CNN forward at B=1 under the two
//! schedulers the runtime supports:
//!
//! * `spawn-per-call` — the legacy `std::thread::scope` dispatcher with
//!   its spawn-priced grains (under which batch-1 layers mostly ran
//!   serial to dodge ~10 µs spawns);
//! * `pool` — the persistent worker pool (dynamic chunk claiming,
//!   spin-then-park wakeups, worker-affine panels), whose cheap dispatch
//!   lets the same layers actually use the cores;
//! * `pool+serve-loop` — the same forward behind the coordinator's
//!   batcher thread (queue + reply channel), i.e. what a served request
//!   sees minus the socket.
//!
//! Emits `BENCH_latency.json` — the first latency datapoint in the bench
//! trajectory. The pool row also reports OS threads spawned during the
//! measured window, which must be zero after warmup.

use espresso::coordinator::{BatchConfig, Coordinator};
use espresso::layers::Backend;
use espresso::net::{mnist_cnn_spec, Network};
use espresso::runtime::NativeEngine;
use espresso::tensor::Tensor;
use espresso::util::parallel::{self, DispatchMode};
use espresso::util::rng::Rng;
use espresso::util::stats::fmt_ns;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Row {
    name: &'static str,
    p50_ns: f64,
    p99_ns: f64,
    mean_ns: f64,
    spawns: u64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Time `iters` calls of `f` (after `warmup` unmeasured calls), capturing
/// the spawn counter across the measured window.
fn measure<F: FnMut()>(name: &'static str, warmup: usize, iters: usize, mut f: F) -> Row {
    for _ in 0..warmup {
        f();
    }
    let spawns0 = parallel::spawn_count();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let spawns = parallel::spawn_count() - spawns0;
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Row {
        name,
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
        mean_ns: mean,
        spawns,
    }
}

fn print_row(r: &Row, baseline_p50: Option<f64>) {
    let speedup = baseline_p50
        .map(|b| format!("{:>7.2}x", b / r.p50_ns))
        .unwrap_or_else(|| "       -".into());
    println!(
        "{:<28} p50 {:>10}  p99 {:>10}  mean {:>10}  {}  ({} spawns)",
        r.name,
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns),
        fmt_ns(r.mean_ns),
        speedup,
        r.spawns
    );
}

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let width: f32 = std::env::var("ESPRESSO_LAT_WIDTH")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 0.5 } else { 1.0 });
    let iters = if quick { 40 } else { 1500 };
    let warmup = if quick { 5 } else { 50 };
    println!(
        "== latency: B=1 MNIST-CNN forward (width={width}, {} threads, {iters} iters) ==",
        parallel::num_threads()
    );

    let mut rng = Rng::new(5);
    let spec = mnist_cnn_spec(&mut rng, width);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    // autotune first: the measured forwards run the registry's chosen
    // micro-kernels, and the JSON rows record which ones
    net.tune();
    net.reserve(1);
    let img = Tensor::from_vec(
        spec.input_shape,
        (0..spec.input_shape.len())
            .map(|_| rng.next_u32() as u8)
            .collect(),
    );

    // --- spawn-per-call baseline (the pre-pool runtime) ---
    parallel::set_dispatch_mode_for_bench(DispatchMode::Spawn);
    let spawn_row = measure("spawn-per-call (legacy)", warmup, iters, || {
        let _ = net.predict_bytes(&img);
    });
    print_row(&spawn_row, None);

    // --- persistent pool ---
    parallel::set_dispatch_mode_for_bench(DispatchMode::Pool);
    parallel::ensure_started(parallel::num_threads());
    let pool_row = measure("persistent pool", warmup, iters, || {
        let _ = net.predict_bytes(&img);
    });
    print_row(&pool_row, Some(spawn_row.p50_ns));

    // --- pool behind the serving loop (batcher thread + channels) ---
    let coord = Coordinator::new(BatchConfig {
        max_batch: 1, // FIFO: the latency-measurement mode, no batch wait
        max_wait: Duration::from_micros(100),
        queue_depth: 64,
        ..BatchConfig::default()
    });
    let engine = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
        "opt",
    )
    .reserved(1);
    coord.register("lat", Arc::new(engine));
    let serve_row = measure("pool+serve-loop", warmup, iters, || {
        let _ = coord.predict("lat", img.clone()).unwrap();
    });
    print_row(&serve_row, Some(spawn_row.p50_ns));

    let speedup = spawn_row.p50_ns / pool_row.p50_ns;
    println!(
        "\npool vs spawn-per-call: {:.2}x p50, {:.2}x p99; {} spawns in {} pooled forwards",
        speedup,
        spawn_row.p99_ns / pool_row.p99_ns,
        pool_row.spawns,
        iters
    );
    let status = parallel::pool_status();
    println!(
        "scheduler: {} workers parked, {} pool jobs, {} inline (below grain), {} inline (busy)",
        status.workers_alive, status.jobs, status.serial_jobs, status.busy_jobs
    );

    // representative tuned kernel: the first plan step with a recorded
    // choice (the leading conv GEMM dominates this forward)
    let simd_name = espresso::bitpack::simd::level_name(espresso::bitpack::simd::level());
    let (kernel, tile_rows) = net
        .plan()
        .steps
        .iter()
        .find_map(|s| s.kernel.get().map(|c| (c.to_string(), c.tile_rows)))
        .unwrap_or_else(|| ("-".to_string(), 0));
    let rows: Vec<String> = [&spawn_row, &pool_row, &serve_row]
        .iter()
        .map(|r| {
            format!(
                "    {{\"name\": \"{}\", \"p50_ns\": {:.0}, \"p99_ns\": {:.0}, \
                 \"mean_ns\": {:.0}, \"spawns_during_measure\": {}, \
                 \"simd_level\": \"{simd_name}\", \"kernel\": \"{kernel}\", \
                 \"tile_rows\": {tile_rows}}}",
                r.name, r.p50_ns, r.p99_ns, r.mean_ns, r.spawns
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"latency_b1_mnist_cnn\",\n  \"arch\": \"{}\",\n  \
         \"threads\": {},\n  \"iters\": {},\n  \"simd_level\": \"{simd_name}\",\n  \
         \"rows\": [\n{}\n  ],\n  \
         \"p50_speedup_pool_vs_spawn\": {:.3},\n  \
         \"pool_spawns_during_measure\": {}\n}}\n",
        net.name,
        parallel::num_threads(),
        iters,
        rows.join(",\n"),
        speedup,
        pool_row.spawns
    );
    // package root and workspace root (whichever the driver inspects)
    let _ = std::fs::write("BENCH_latency.json", &json);
    let _ = std::fs::write("../BENCH_latency.json", &json);
    println!("(wrote BENCH_latency.json; bar: pool p50 >= 1.5x over spawn-per-call at B=1)");
}
