//! **Serving throughput over real TCP** — closed-loop clients against
//! both front ends on a binary MLP.
//!
//! A/B over `--io-model`: the event-driven front end (epoll loops, one
//! per core) runs c ∈ {1, 8, 32, 256, 1024} concurrent connections; the
//! thread-per-connection baseline runs c ∈ {1, 8, 32} (it spends 2 OS
//! threads per socket, so the high-concurrency rows are exactly what it
//! cannot do). Each row records req/s, client-observed latency, and the
//! serving thread count sampled mid-run — the event rows must stay
//! bounded by cores + a constant while c grows 1000×. A final
//! single-connection `predict_batch` row (op 5) shows one socket
//! saturating GEMM-level batching without any connection-level
//! concurrency. Writes `BENCH_serve.json`.

use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::NativeEngine;
use espresso::util::rng::Rng;
use espresso::util::stats::{fmt_ns, Summary};
use espresso::util::{os_thread_count, Timer};
use std::sync::Arc;
use std::time::Duration;

/// Client threads only push bytes through a socket: a small stack keeps
/// the c=1024 row cheap to spawn.
const CLIENT_STACK: usize = 128 * 1024;

/// Connect with retry/backoff: a burst of simultaneous connects at high
/// c can outrun the accept queue.
fn connect_retry(addr: &str) -> tcp::Client {
    let mut delay = Duration::from_millis(1);
    for _ in 0..10 {
        match tcp::Client::connect(addr) {
            Ok(c) => return c,
            Err(_) => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(100));
            }
        }
    }
    tcp::Client::connect(addr).unwrap()
}

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let hidden = if quick { 256 } else { 1024 };
    let per_client = if quick { 40 } else { 400 };
    let max_batch = 32;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("== serve: closed-loop TCP clients, event vs threads front end ==");
    println!(
        "model: bmlp 784-{hidden}x2-10, max_batch {max_batch}, queue_depth 4096, {cores} cores"
    );

    let mut rng = Rng::new(51);
    let spec = bmlp_spec(&mut rng, hidden, 2);
    let imgs: Vec<Vec<u8>> = (0..256)
        .map(|_| (0..784).map(|_| rng.next_u32() as u8).collect())
        .collect();
    let mut rows = Vec::new();

    println!(
        "{:>9} {:>14} {:>9} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "io", "clients", "requests", "req/s", "p50", "p95", "batch", "threads"
    );
    for &io in &[tcp::IoModel::Event, tcp::IoModel::Threads] {
        // fresh server per model so metrics and connection state don't
        // bleed across the A/B halves
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let coord = Arc::new(Coordinator::new(BatchConfig {
            max_batch,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
        }));
        coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt").reserved(max_batch)));
        let handle = tcp::serve(
            coord.clone(),
            "127.0.0.1:0",
            tcp::ServeOptions {
                max_conns: 2048,
                io_model: io,
                io_loops: 0,
            },
        )
        .unwrap();
        let addr = handle.addr().to_string();
        let io_name = match io {
            tcp::IoModel::Event => "event",
            tcp::IoModel::Threads => "threads",
        };
        // the event loop's thread count is the point of the high-c rows;
        // the threaded baseline stops at 32 (2 threads/conn beyond that
        // measures the OS scheduler, not the serving path)
        let concurrencies: &[usize] = match io {
            tcp::IoModel::Event => &[1, 8, 32, 256, 1024],
            tcp::IoModel::Threads => &[1, 8, 32],
        };
        for &clients in concurrencies {
            // keep total work comparable as c grows: the high-c rows
            // measure multiplexing, they don't need 1000× the requests
            let per_c = if clients > 32 {
                (per_client / 10).max(4)
            } else {
                per_client
            };
            let before = coord
                .metrics
                .snapshot("bmlp")
                .map(|s| (s.requests, s.batches))
                .unwrap_or((0, 0));
            let wall = Timer::start();
            let (lats, serve_threads, os_threads) = std::thread::scope(|s| {
                let mut handles = Vec::new();
                for c in 0..clients {
                    let addr = addr.clone();
                    let imgs = &imgs;
                    handles.push(
                        std::thread::Builder::new()
                            .stack_size(CLIENT_STACK)
                            .spawn_scoped(s, move || {
                                // stagger the connect burst at high c
                                if clients > 64 {
                                    std::thread::sleep(Duration::from_micros(
                                        (c as u64 % 64) * 200,
                                    ));
                                }
                                let mut client = connect_retry(&addr);
                                let mut lats = Vec::with_capacity(per_c);
                                for r in 0..per_c {
                                    let img = &imgs[(c * per_c + r) % imgs.len()];
                                    let t = Timer::start();
                                    client.predict("bmlp", img).unwrap();
                                    lats.push(t.elapsed_ns() as f64);
                                }
                                lats
                            })
                            .unwrap(),
                    );
                }
                // sample the thread counts mid-run, while every client
                // connection is live
                std::thread::sleep(Duration::from_millis(30));
                let serve_threads = handle.serving_threads();
                let os_threads = os_thread_count();
                let lats: Vec<f64> =
                    handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
                (lats, serve_threads, os_threads)
            });
            let wall_s = wall.elapsed_s();
            let total = clients * per_c;
            let rps = total as f64 / wall_s;
            let after = coord.metrics.snapshot("bmlp").unwrap();
            let batches = (after.batches - before.1).max(1);
            let mean_batch = (after.requests - before.0) as f64 / batches as f64;
            let summary = Summary::from(&lats);
            println!(
                "{:>9} {:>14} {:>9} {:>10.0} {:>10} {:>10} {:>8.1} {:>8}",
                io_name,
                clients,
                total,
                rps,
                fmt_ns(summary.p50),
                fmt_ns(summary.p95),
                mean_batch,
                serve_threads
            );
            rows.push(format!(
                "    {{\"io_model\": \"{io_name}\", \"clients\": {clients}, \"wire_batch\": 1, \
                 \"requests\": {total}, \"reqs_per_sec\": {rps:.0}, \"p50_ns\": {:.0}, \
                 \"p95_ns\": {:.0}, \"mean_batch\": {mean_batch:.2}, \
                 \"serve_threads\": {serve_threads}, \"os_threads\": {}}}",
                summary.p50,
                summary.p95,
                os_threads
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| "null".into())
            ));
            if io == tcp::IoModel::Event {
                // the acceptance bar: serving threads bounded by cores +
                // constant no matter how many sockets are live
                assert!(
                    serve_threads <= cores + 2,
                    "event front end used {serve_threads} serving threads at c={clients} \
                     (bound: {cores} cores + 2)"
                );
            }
        }

        if io == tcp::IoModel::Event {
            // one connection, predict_batch frames of 64: wire-level
            // batching replaces connection-level concurrency
            let wire = 64usize;
            let total = if quick { 320 } else { 3200 };
            let before = coord
                .metrics
                .snapshot("bmlp")
                .map(|s| (s.requests, s.batches))
                .unwrap_or((0, 0));
            let mut client = tcp::Client::connect(&addr).unwrap();
            let wall = Timer::start();
            let mut done = 0usize;
            while done < total {
                let n = wire.min(total - done);
                let refs: Vec<&[u8]> = (0..n)
                    .map(|r| imgs[(done + r) % imgs.len()].as_slice())
                    .collect();
                for reply in client.predict_batch("bmlp", &refs).unwrap() {
                    reply.scores().unwrap();
                }
                done += n;
            }
            let wall_s = wall.elapsed_s();
            let rps = total as f64 / wall_s;
            let after = coord.metrics.snapshot("bmlp").unwrap();
            let batches = (after.batches - before.1).max(1);
            let mean_batch = (after.requests - before.0) as f64 / batches as f64;
            let label = format!("1 (op5 x{wire})");
            println!(
                "{:>9} {:>14} {:>9} {:>10.0} {:>10} {:>10} {:>8.1} {:>8}",
                io_name,
                label,
                total,
                rps,
                "-",
                "-",
                mean_batch,
                handle.serving_threads()
            );
            rows.push(format!(
                "    {{\"io_model\": \"{io_name}\", \"clients\": 1, \"wire_batch\": {wire}, \
                 \"requests\": {total}, \"reqs_per_sec\": {rps:.0}, \"p50_ns\": null, \
                 \"p95_ns\": null, \"mean_batch\": {mean_batch:.2}, \
                 \"serve_threads\": {}, \"os_threads\": null}}",
                handle.serving_threads()
            ));
        }
    }
    println!(
        "(event rows hold serving threads at cores + accept thread while c grows 1000×; \
         wire batching lets one socket reach GEMM-level batch sizes)"
    );

    let json = format!(
        "{{\n  \"bench\": \"serve_closed_loop\",\n  \"arch\": \"{}\",\n  \"max_batch\": {max_batch},\n  \"cores\": {cores},\n  \"rows\": [\n{}\n  ]\n}}\n",
        spec.name,
        rows.join(",\n")
    );
    // package root and workspace root (whichever the driver inspects)
    let _ = std::fs::write("BENCH_serve.json", &json);
    let _ = std::fs::write("../BENCH_serve.json", &json);
}
