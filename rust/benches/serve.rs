//! **Serving throughput over real TCP** — closed-loop clients against
//! the event-driven front end on a binary MLP.
//!
//! Three sections:
//!  1. Concurrency sweep at R=1: c ∈ {1, 8, 32, 256, 1024} closed-loop
//!     connections. Each row records req/s, client-observed latency, and
//!     the serving thread count sampled mid-run — bounded by cores + a
//!     constant while c grows 1000×.
//!  2. A single-connection `predict_batch` row (op 5): one socket
//!     saturating GEMM-level batching without connection concurrency.
//!  3. Replica sweep at c=256: R ∈ {1, 2, 4} engine replicas behind
//!     least-loaded dispatch, reporting req/s plus the per-replica share
//!     of served requests (utilization balance).
//!
//! Writes `BENCH_serve.json`.

use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::{Engine, NativeEngine};
use espresso::util::rng::Rng;
use espresso::util::stats::{fmt_ns, Summary};
use espresso::util::{os_thread_count, Timer};
use std::sync::Arc;
use std::time::Duration;

/// Client threads only push bytes through a socket: a small stack keeps
/// the c=1024 row cheap to spawn.
const CLIENT_STACK: usize = 128 * 1024;

/// Connect with retry/backoff: a burst of simultaneous connects at high
/// c can outrun the accept queue.
fn connect_retry(addr: &str) -> tcp::Client {
    let mut delay = Duration::from_millis(1);
    for _ in 0..10 {
        match tcp::Client::connect(addr) {
            Ok(c) => return c,
            Err(_) => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(100));
            }
        }
    }
    tcp::Client::connect(addr).unwrap()
}

struct Run {
    rps: f64,
    p50: f64,
    p95: f64,
    mean_batch: f64,
    serve_threads: usize,
    os_threads: Option<usize>,
    total: usize,
}

/// One closed-loop measurement: `clients` connections × `per_c` requests.
fn closed_loop(
    coord: &Arc<Coordinator>,
    handle: &tcp::ServerHandle,
    imgs: &[Vec<u8>],
    clients: usize,
    per_c: usize,
) -> Run {
    let addr = handle.addr().to_string();
    let before = coord
        .metrics
        .snapshot("bmlp")
        .map(|s| (s.requests, s.batches))
        .unwrap_or((0, 0));
    let wall = Timer::start();
    let (lats, serve_threads, os_threads) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            handles.push(
                std::thread::Builder::new()
                    .stack_size(CLIENT_STACK)
                    .spawn_scoped(s, move || {
                        // stagger the connect burst at high c
                        if clients > 64 {
                            std::thread::sleep(Duration::from_micros((c as u64 % 64) * 200));
                        }
                        let mut client = connect_retry(&addr);
                        let mut lats = Vec::with_capacity(per_c);
                        for r in 0..per_c {
                            let img = &imgs[(c * per_c + r) % imgs.len()];
                            let t = Timer::start();
                            client.predict("bmlp", img).unwrap();
                            lats.push(t.elapsed_ns() as f64);
                        }
                        lats
                    })
                    .unwrap(),
            );
        }
        // sample the thread counts mid-run, while every client
        // connection is live
        std::thread::sleep(Duration::from_millis(30));
        let serve_threads = handle.serving_threads();
        let os_threads = os_thread_count();
        let lats: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        (lats, serve_threads, os_threads)
    });
    let wall_s = wall.elapsed_s();
    let total = clients * per_c;
    let after = coord.metrics.snapshot("bmlp").unwrap();
    let batches = (after.batches - before.1).max(1);
    let summary = Summary::from(&lats);
    Run {
        rps: total as f64 / wall_s,
        p50: summary.p50,
        p95: summary.p95,
        mean_batch: (after.requests - before.0) as f64 / batches as f64,
        serve_threads,
        os_threads,
        total,
    }
}

fn serve_replicated(
    spec: &espresso::format::ModelSpec,
    replicas: usize,
    max_batch: usize,
) -> (Arc<Coordinator>, tcp::ServerHandle) {
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch,
        max_wait: Duration::from_micros(200),
        queue_depth: 4096,
        ..BatchConfig::default()
    }));
    let engines: Vec<Arc<dyn Engine>> = (0..replicas)
        .map(|_| {
            let net = Network::<u64>::from_spec(spec, Backend::Binary).unwrap();
            Arc::new(NativeEngine::new(net, "opt").reserved(max_batch)) as Arc<dyn Engine>
        })
        .collect();
    coord.register_replicated("bmlp", engines);
    let handle = tcp::serve(
        coord.clone(),
        "127.0.0.1:0",
        tcp::ServeOptions {
            max_conns: 2048,
            io_loops: 0,
            ..tcp::ServeOptions::default()
        },
    )
    .unwrap();
    (coord, handle)
}

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let hidden = if quick { 256 } else { 1024 };
    let per_client = if quick { 40 } else { 400 };
    let max_batch = 32;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("== serve: closed-loop TCP clients, event front end + replica sweep ==");
    println!(
        "model: bmlp 784-{hidden}x2-10, max_batch {max_batch}, queue_depth 4096, {cores} cores"
    );

    let mut rng = Rng::new(51);
    let spec = bmlp_spec(&mut rng, hidden, 2);
    let imgs: Vec<Vec<u8>> = (0..256)
        .map(|_| (0..784).map(|_| rng.next_u32() as u8).collect())
        .collect();
    let mut rows = Vec::new();

    println!(
        "{:>9} {:>14} {:>9} {:>10} {:>10} {:>10} {:>8} {:>8}",
        "replicas", "clients", "requests", "req/s", "p50", "p95", "batch", "threads"
    );

    // -- section 1: concurrency sweep, single replica ---------------------
    let (coord, handle) = serve_replicated(&spec, 1, max_batch);
    for &clients in &[1usize, 8, 32, 256, 1024] {
        // keep total work comparable as c grows: the high-c rows measure
        // multiplexing, they don't need 1000× the requests
        let per_c = if clients > 32 {
            (per_client / 10).max(4)
        } else {
            per_client
        };
        let run = closed_loop(&coord, &handle, &imgs, clients, per_c);
        println!(
            "{:>9} {:>14} {:>9} {:>10.0} {:>10} {:>10} {:>8.1} {:>8}",
            1,
            clients,
            run.total,
            run.rps,
            fmt_ns(run.p50),
            fmt_ns(run.p95),
            run.mean_batch,
            run.serve_threads
        );
        rows.push(format!(
            "    {{\"io_model\": \"event\", \"replicas\": 1, \"clients\": {clients}, \
             \"wire_batch\": 1, \"requests\": {}, \"reqs_per_sec\": {:.0}, \
             \"p50_ns\": {:.0}, \"p95_ns\": {:.0}, \"mean_batch\": {:.2}, \
             \"serve_threads\": {}, \"os_threads\": {}, \"replica_served\": [{}]}}",
            run.total,
            run.rps,
            run.p50,
            run.p95,
            run.mean_batch,
            run.serve_threads,
            run.os_threads
                .map(|n| n.to_string())
                .unwrap_or_else(|| "null".into()),
            run.total
        ));
        // the acceptance bar: serving threads bounded by cores + constant
        // no matter how many sockets are live
        assert!(
            run.serve_threads <= cores + 2,
            "event front end used {} serving threads at c={clients} (bound: {cores} cores + 2)",
            run.serve_threads
        );
    }

    // -- section 2: one connection, predict_batch frames of 64 ------------
    {
        let wire = 64usize;
        let total = if quick { 320 } else { 3200 };
        let before = coord
            .metrics
            .snapshot("bmlp")
            .map(|s| (s.requests, s.batches))
            .unwrap_or((0, 0));
        let mut client = tcp::Client::connect(&handle.addr().to_string()).unwrap();
        let wall = Timer::start();
        let mut done = 0usize;
        while done < total {
            let n = wire.min(total - done);
            let refs: Vec<&[u8]> = (0..n)
                .map(|r| imgs[(done + r) % imgs.len()].as_slice())
                .collect();
            for reply in client.predict_batch("bmlp", &refs).unwrap() {
                reply.scores().unwrap();
            }
            done += n;
        }
        let wall_s = wall.elapsed_s();
        let rps = total as f64 / wall_s;
        let after = coord.metrics.snapshot("bmlp").unwrap();
        let batches = (after.batches - before.1).max(1);
        let mean_batch = (after.requests - before.0) as f64 / batches as f64;
        println!(
            "{:>9} {:>14} {:>9} {:>10.0} {:>10} {:>10} {:>8.1} {:>8}",
            1,
            format!("1 (op5 x{wire})"),
            total,
            rps,
            "-",
            "-",
            mean_batch,
            handle.serving_threads()
        );
        rows.push(format!(
            "    {{\"io_model\": \"event\", \"replicas\": 1, \"clients\": 1, \
             \"wire_batch\": {wire}, \"requests\": {total}, \"reqs_per_sec\": {rps:.0}, \
             \"p50_ns\": null, \"p95_ns\": null, \"mean_batch\": {mean_batch:.2}, \
             \"serve_threads\": {}, \"os_threads\": null, \"replica_served\": [{total}]}}",
            handle.serving_threads()
        ));
    }
    drop(handle);
    drop(coord);

    // -- section 3: replica sweep at c=256 --------------------------------
    // The tentpole measurement: R engine replicas behind least-loaded
    // dispatch, same model, same concurrency. Each replica owns its own
    // batcher + scratch pools, so GEMM-level work parallelizes across
    // replicas instead of serializing behind one batch loop.
    let sweep_clients = 256usize;
    let sweep_per_c = (per_client / 10).max(4);
    let mut r1_rps = None;
    for &replicas in &[1usize, 2, 4] {
        let (coord, handle) = serve_replicated(&spec, replicas, max_batch);
        let run = closed_loop(&coord, &handle, &imgs, sweep_clients, sweep_per_c);
        let served = coord.metrics.replica_served("bmlp");
        let total_served: u64 = served.iter().sum::<u64>().max(1);
        let shares: Vec<String> = served
            .iter()
            .map(|&n| format!("{:.0}%", 100.0 * n as f64 / total_served as f64))
            .collect();
        println!(
            "{:>9} {:>14} {:>9} {:>10.0} {:>10} {:>10} {:>8.1} {:>8}  util [{}]",
            replicas,
            sweep_clients,
            run.total,
            run.rps,
            fmt_ns(run.p50),
            fmt_ns(run.p95),
            run.mean_batch,
            run.serve_threads,
            shares.join(" ")
        );
        rows.push(format!(
            "    {{\"io_model\": \"event\", \"replicas\": {replicas}, \
             \"clients\": {sweep_clients}, \"wire_batch\": 1, \"requests\": {}, \
             \"reqs_per_sec\": {:.0}, \"p50_ns\": {:.0}, \"p95_ns\": {:.0}, \
             \"mean_batch\": {:.2}, \"serve_threads\": {}, \"os_threads\": {}, \
             \"replica_served\": [{}]}}",
            run.total,
            run.rps,
            run.p50,
            run.p95,
            run.mean_batch,
            run.serve_threads,
            run.os_threads
                .map(|n| n.to_string())
                .unwrap_or_else(|| "null".into()),
            served
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ));
        if replicas == 1 {
            r1_rps = Some(run.rps);
        } else if let Some(base) = r1_rps {
            println!(
                "           (R={replicas}: {:.2}x the R=1 rate)",
                run.rps / base
            );
        }
    }
    println!(
        "(serving threads stay at the loop count while c grows 1000×; replicas scale \
         batch-level GEMM work across independent engine pools; wire batching lets one \
         socket reach GEMM-level batch sizes)"
    );

    let json = format!(
        "{{\n  \"bench\": \"serve_closed_loop\",\n  \"arch\": \"{}\",\n  \"max_batch\": {max_batch},\n  \"cores\": {cores},\n  \"rows\": [\n{}\n  ]\n}}\n",
        spec.name,
        rows.join(",\n")
    );
    // package root and workspace root (whichever the driver inspects)
    let _ = std::fs::write("BENCH_serve.json", &json);
    let _ = std::fs::write("../BENCH_serve.json", &json);
}
