//! **Serving throughput over real TCP** — the first serving datapoint in
//! the perf trajectory.
//!
//! Closed-loop clients against the pipelined front end on a binary MLP:
//! req/s and client-observed latency at c ∈ {1, 8, 32} concurrent
//! connections, plus a single-connection `predict_batch` row (op 5) that
//! shows one socket saturating GEMM-level batching without any
//! connection-level concurrency. Writes `BENCH_serve.json`.

use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::NativeEngine;
use espresso::util::rng::Rng;
use espresso::util::stats::{fmt_ns, Summary};
use espresso::util::Timer;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let hidden = if quick { 256 } else { 1024 };
    let per_client = if quick { 40 } else { 400 };
    let max_batch = 32;
    println!("== serve: closed-loop TCP clients vs pipelined front end ==");
    println!("model: bmlp 784-{hidden}x2-10, max_batch {max_batch}, queue_depth 4096");

    let mut rng = Rng::new(51);
    let spec = bmlp_spec(&mut rng, hidden, 2);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch,
        max_wait: Duration::from_micros(200),
        queue_depth: 4096,
    }));
    coord.register("bmlp", Arc::new(NativeEngine::new(net, "opt").reserved(max_batch)));
    let handle = tcp::serve(coord.clone(), "127.0.0.1:0", tcp::ServeOptions::default()).unwrap();
    let addr = handle.addr().to_string();
    let imgs: Vec<Vec<u8>> = (0..256)
        .map(|_| (0..784).map(|_| rng.next_u32() as u8).collect())
        .collect();

    println!(
        "{:>12} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "clients", "requests", "req/s", "p50", "p95", "batch"
    );
    let mut rows = Vec::new();
    for &clients in &[1usize, 8, 32] {
        let before = coord
            .metrics
            .snapshot("bmlp")
            .map(|s| (s.requests, s.batches))
            .unwrap_or((0, 0));
        let wall = Timer::start();
        let lats: Vec<f64> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for c in 0..clients {
                let addr = addr.clone();
                let imgs = &imgs;
                handles.push(s.spawn(move || {
                    let mut client = tcp::Client::connect(&addr).unwrap();
                    let mut lats = Vec::with_capacity(per_client);
                    for r in 0..per_client {
                        let img = &imgs[(c * per_client + r) % imgs.len()];
                        let t = Timer::start();
                        client.predict("bmlp", img).unwrap();
                        lats.push(t.elapsed_ns() as f64);
                    }
                    lats
                }));
            }
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let wall_s = wall.elapsed_s();
        let total = clients * per_client;
        let rps = total as f64 / wall_s;
        let after = coord.metrics.snapshot("bmlp").unwrap();
        let batches = (after.batches - before.1).max(1);
        let mean_batch = (after.requests - before.0) as f64 / batches as f64;
        let summary = Summary::from(&lats);
        println!(
            "{:>12} {:>9} {:>10.0} {:>10} {:>10} {:>10.1}",
            clients,
            total,
            rps,
            fmt_ns(summary.p50),
            fmt_ns(summary.p95),
            mean_batch
        );
        rows.push(format!(
            "    {{\"clients\": {clients}, \"wire_batch\": 1, \"requests\": {total}, \
             \"reqs_per_sec\": {rps:.0}, \"p50_ns\": {:.0}, \"p95_ns\": {:.0}, \
             \"mean_batch\": {mean_batch:.2}}}",
            summary.p50, summary.p95
        ));
    }

    // one connection, predict_batch frames of 64: wire-level batching
    // replaces connection-level concurrency
    let wire = 64usize;
    let total = if quick { 320 } else { 3200 };
    let before = coord
        .metrics
        .snapshot("bmlp")
        .map(|s| (s.requests, s.batches))
        .unwrap_or((0, 0));
    let mut client = tcp::Client::connect(&addr).unwrap();
    let wall = Timer::start();
    let mut done = 0usize;
    while done < total {
        let n = wire.min(total - done);
        let refs: Vec<&[u8]> = (0..n)
            .map(|r| imgs[(done + r) % imgs.len()].as_slice())
            .collect();
        for reply in client.predict_batch("bmlp", &refs).unwrap() {
            reply.scores().unwrap();
        }
        done += n;
    }
    let wall_s = wall.elapsed_s();
    let rps = total as f64 / wall_s;
    let after = coord.metrics.snapshot("bmlp").unwrap();
    let batches = (after.batches - before.1).max(1);
    let mean_batch = (after.requests - before.0) as f64 / batches as f64;
    println!(
        "{:>12} {:>9} {:>10.0} {:>10} {:>10} {:>10.1}",
        format!("1 (op5 x{wire})"),
        total,
        rps,
        "-",
        "-",
        mean_batch
    );
    rows.push(format!(
        "    {{\"clients\": 1, \"wire_batch\": {wire}, \"requests\": {total}, \
         \"reqs_per_sec\": {rps:.0}, \"p50_ns\": null, \"p95_ns\": null, \
         \"mean_batch\": {mean_batch:.2}}}"
    ));
    println!("(wire batching lets one socket reach GEMM-level batch sizes; req/s should scale with c)");

    let json = format!(
        "{{\n  \"bench\": \"serve_closed_loop\",\n  \"arch\": \"{}\",\n  \"max_batch\": {max_batch},\n  \"rows\": [\n{}\n  ]\n}}\n",
        spec.name,
        rows.join(",\n")
    );
    // package root and workspace root (whichever the driver inspects)
    let _ = std::fs::write("BENCH_serve.json", &json);
    let _ = std::fs::write("../BENCH_serve.json", &json);
}
