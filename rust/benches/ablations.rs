//! **Ablation benches** for the in-text claims of §6.2/§6.1 and the
//! design choices DESIGN.md calls out:
//!
//! * **A1** — first-layer bit-plane optimization (paper: ≈3× whole-net).
//! * **A2** — pre-packing vs pack-per-forward at the layer level
//!   (BinaryNet's principal overhead).
//! * **A3** — GEMV vs GEMM at batch 1 (paper: ≈15%).
//! * **F1** — unroll (im2col) cost within a binary conv, and packed
//!   OR-pooling vs int32 pooling (layout/lift claims of §5.1–5.2).
//! * **B1** — dynamic batching (batched GEMM amortization; coordinator).

use espresso::bitpack::{self, pack_matrix_cols, pack_matrix_rows};
use espresso::format::{InputKind, LayerSpec, ModelSpec};
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::runtime::NativeEngine;
use espresso::tensor::{unroll_bits, BitTensor, PackDir, Shape, Tensor};
use espresso::util::bench::{bench, BenchConfig, BenchTable};
use espresso::util::rng::Rng;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    a1_first_layer(quick);
    a1_conv_first_layer(quick);
    a2_prepacking(quick);
    a3_gemv_vs_gemm(quick);
    f1_unroll_and_pool(quick);
    b1_batching(quick);
}

fn cfg(quick: bool) -> BenchConfig {
    BenchConfig {
        warmup_iters: 2,
        min_iters: if quick { 3 } else { 10 },
        max_iters: if quick { 5 } else { 50 },
        measure_time: std::time::Duration::from_secs(if quick { 2 } else { 8 }),
    }
}

/// A1: whole-network BMLP with the first layer binary-optimized
/// (bit-planes) vs computed in float (BinaryNet behaviour).
fn a1_first_layer(quick: bool) {
    let hidden = if quick { 1024 } else { 4096 };
    println!("== A1: first-layer bit-plane optimization (BMLP {hidden}x3) ==");
    let mut rng = Rng::new(11);
    let spec = bmlp_spec(&mut rng, hidden, 3);
    let mut spec_nobp = spec.clone();
    if let LayerSpec::Dense { bitplane_first, .. } = &mut spec_nobp.layers[0] {
        *bitplane_first = false;
    }
    let with_bp = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let without = Network::<u64>::from_spec(&spec_nobp, Backend::Binary).unwrap();
    let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
    let img = Tensor::from_vec(Shape::vector(784), img);
    assert_eq!(with_bp.predict_bytes(&img), without.predict_bytes(&img));

    let c = cfg(quick);
    let mut t = BenchTable::new("A1 first-layer binarization").baseline("first layer float (BinaryNet-style)");
    t.push(bench("first layer float (BinaryNet-style)", &c, || {
        let _ = without.predict_bytes(&img);
    }));
    t.push(bench("first layer bit-planes (Espresso)", &c, || {
        let _ = with_bp.predict_bytes(&img);
    }));
    println!("{}", t.render());
    println!("paper: ~3x whole-network gain from first-layer binary optimization\n");
    save("a1_first_layer", &t);
}

/// A1-conv (extension): the bit-plane trick generalized to the CNN's
/// first layer — whole-network BCNN with/without it.
fn a1_conv_first_layer(quick: bool) {
    let width = if quick { 0.25 } else { 1.0 };
    println!("== A1-conv: bit-plane first conv layer (BCNN width={width}) ==");
    let mut rng = Rng::new(16);
    let spec = crate_bcnn(&mut rng, width, true);
    let spec_nobp = crate_bcnn_from(&spec, false);
    let with_bp = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let without = Network::<u64>::from_spec(&spec_nobp, Backend::Binary).unwrap();
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u32() as u8).collect();
    let img = Tensor::from_vec(Shape::new(32, 32, 3), img);
    assert_eq!(with_bp.predict_bytes(&img), without.predict_bytes(&img));

    let c = cfg(quick);
    let mut t = BenchTable::new("A1-conv first-layer binarization")
        .baseline("first conv layer float (BinaryNet-style)");
    t.push(bench("first conv layer float (BinaryNet-style)", &c, || {
        let _ = without.predict_bytes(&img);
    }));
    t.push(bench("first conv layer bit-planes (Espresso ext.)", &c, || {
        let _ = with_bp.predict_bytes(&img);
    }));
    println!("{}", t.render());
    save("a1_conv", &t);
}

fn crate_bcnn(rng: &mut Rng, width: f32, bitplane: bool) -> ModelSpec {
    let mut spec = espresso::net::bcnn_spec(rng, width);
    set_first_conv_bitplane(&mut spec, bitplane);
    spec
}

fn crate_bcnn_from(spec: &ModelSpec, bitplane: bool) -> ModelSpec {
    let mut s = spec.clone();
    set_first_conv_bitplane(&mut s, bitplane);
    s
}

fn set_first_conv_bitplane(spec: &mut ModelSpec, v: bool) {
    if let Some(LayerSpec::Conv { bitplane_first, .. }) = spec.layers.first_mut() {
        *bitplane_first = v;
    }
}

/// A2: one 4096x4096 dense layer — prepacked weights vs packing the
/// weight matrix on every call (row- and column-packers).
fn a2_prepacking(quick: bool) {
    let n = if quick { 1024 } else { 4096 };
    println!("== A2: pre-packing vs pack-per-forward (dense {n}x{n}, batch 1) ==");
    let mut rng = Rng::new(12);
    let w = rng.signs(n * n);
    let mut w_t = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            w_t[j * n + i] = w[i * n + j];
        }
    }
    let x = rng.signs(n);
    let px = pack_matrix_rows::<u64>(&x, 1, n);
    let pw = pack_matrix_rows::<u64>(&w, n, n);
    let mut out = vec![0i32; n];

    let c = cfg(quick);
    let mut t = BenchTable::new("A2 packing policy").baseline("pack per forward (columns, BinaryNet)");
    t.push(bench("pack per forward (columns, BinaryNet)", &c, || {
        let pb = pack_matrix_cols::<u64>(&w_t, n, n);
        bitpack::gemv_into::<u64>(&px, &pb, &mut out, n, n);
    }));
    t.push(bench("pack per forward (rows, neon-like)", &c, || {
        let pb = pack_matrix_rows::<u64>(&w, n, n);
        bitpack::gemv_into::<u64>(&px, &pb, &mut out, n, n);
    }));
    t.push(bench("prepacked at load (Espresso)", &c, || {
        bitpack::gemv_into::<u64>(&px, &pw, &mut out, n, n);
    }));
    println!("{}", t.render());
    println!("paper: packing cost ~ the multiplication itself; col-packer ~4x slower than row-packer\n");
    save("a2_prepacking", &t);
}

/// A3: batch-1 dense layer through the GEMM kernel vs the dedicated GEMV.
fn a3_gemv_vs_gemm(quick: bool) {
    let n = if quick { 1024 } else { 4096 };
    println!("== A3: GEMV vs GEMM at batch 1 (dense {n}x{n}) ==");
    let mut rng = Rng::new(13);
    let w = rng.signs(n * n);
    let x = rng.signs(n);
    let px = pack_matrix_rows::<u64>(&x, 1, n);
    let pw = pack_matrix_rows::<u64>(&w, n, n);
    let mut out = vec![0i32; n];

    let c = cfg(quick);
    let mut t = BenchTable::new("A3 kernel selection").baseline("matrix-matrix kernel (m=1)");
    t.push(bench("matrix-matrix kernel (m=1)", &c, || {
        bitpack::gemm_into::<u64>(&px, &pw, &mut out, 1, n, n);
    }));
    t.push(bench("matrix-vector kernel", &c, || {
        bitpack::gemv_into::<u64>(&px, &pw, &mut out, n, n);
    }));
    println!("{}", t.render());
    println!("paper: ~15% gain from the dedicated GEMV at batch 1\n");
    save("a3_gemv", &t);
}

/// F1: binary conv pipeline decomposition — unroll cost relative to the
/// GEMM (the layout claim: channel packing makes unrolling word copies),
/// and OR-pooling packed bits vs pooling int32 accumulators.
fn f1_unroll_and_pool(quick: bool) {
    let (hw, ch, f) = if quick { (16, 128, 128) } else { (16, 256, 256) };
    println!("== F1: unroll/lift + pooling on packed tensors (conv {hw}x{hw}x{ch} -> {f}) ==");
    let mut rng = Rng::new(14);
    let s = Shape::new(hw, hw, ch);
    let mut d = vec![0f32; s.len()];
    rng.fill_signs(&mut d);
    let t_in = Tensor::from_vec(s, d);
    let bt = BitTensor::<u64>::from_tensor_dir(&t_in, PackDir::Channels);
    let lw = bt.group_words;
    let rows = hw * hw;
    let row_words = 9 * lw;
    let k_bits = 9 * ch;
    let wts = rng.signs(f * 9 * ch);
    let pf = espresso::tensor::pack_filters::<u64>(&wts, f, 3, 3, ch);
    let mut unrolled = vec![0u64; rows * row_words];
    let mut acc = vec![0i32; rows * f];

    let c = cfg(quick);
    let mut t = BenchTable::new("F1 conv pipeline").baseline("unroll + gemm (full conv)");
    t.push(bench("unroll + gemm (full conv)", &c, || {
        unroll_bits(&bt, 3, 3, 1, 1, &mut unrolled);
        bitpack::gemm_words_into::<u64>(&unrolled, &pf, &mut acc, rows, f, row_words, k_bits);
    }));
    t.push(bench("gemm only (prev. unrolled)", &c, || {
        bitpack::gemm_words_into::<u64>(&unrolled, &pf, &mut acc, rows, f, row_words, k_bits);
    }));
    t.push(bench("unroll only (packed word copies)", &c, || {
        unroll_bits(&bt, 3, 3, 1, 1, &mut unrolled);
    }));

    // pooling variants over the conv output
    let conv_bits = {
        // threshold at 0 to get packed bits for the OR-pool variant
        let tau = vec![0f32; f];
        let gpos = vec![true; f];
        let lw_out = espresso::bitpack::words_for::<u64>(f);
        let mut data = vec![0u64; rows * lw_out];
        for p in 0..rows {
            espresso::bitpack::pack_thresholds_into(
                &acc[p * f..(p + 1) * f],
                &tau,
                &gpos,
                &mut data[p * lw_out..(p + 1) * lw_out],
            );
        }
        BitTensor::<u64> {
            shape: Shape::new(hw, hw, f),
            batch: 1,
            dir: PackDir::Channels,
            group_words: lw_out,
            data,
        }
    };
    let pool = espresso::layers::MaxPoolLayer::new(2, 2);
    let ws = espresso::alloc::Workspace::new();
    t.push(bench("pool packed bits (OR words)", &c, || {
        use espresso::layers::{Act, Layer};
        let _ = Layer::<u64>::forward(
            &pool,
            Act::Bits(conv_bits.clone()),
            Backend::Binary,
            &ws,
        );
    }));
    let conv_float = conv_bits.to_tensor();
    t.push(bench("pool float channels", &c, || {
        use espresso::layers::{Act, Layer};
        let _ = Layer::<u64>::forward(
            &pool,
            Act::Float(conv_float.clone()),
            Backend::Float,
            &ws,
        );
    }));
    println!("{}", t.render());
    println!("Fig.1 claim: lift is free (GEMM output is already the output tensor); unroll is word copies\n");
    save("f1_unroll", &t);
}

/// B1: coordinator dynamic batching — requests/s at max_batch 1 vs 8.
fn b1_batching(quick: bool) {
    use espresso::coordinator::{BatchConfig, Coordinator};
    use std::sync::Arc;
    let hidden = if quick { 512 } else { 2048 };
    println!("== B1: dynamic batching throughput (BMLP {hidden}x2) ==");
    let mut rng = Rng::new(15);
    let spec = bmlp_spec(&mut rng, hidden, 2);
    let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
    let img = Tensor::from_vec(Shape::vector(784), img);
    let n_reqs = if quick { 200 } else { 1000 };
    for max_batch in [1usize, 4, 16] {
        let coord = Coordinator::new(BatchConfig {
            max_batch,
            max_wait: std::time::Duration::from_micros(300),
            ..BatchConfig::default()
        });
        let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        coord.register("m", Arc::new(NativeEngine::new(net, "opt")));
        let t = espresso::util::Timer::start();
        let handles: Vec<_> = (0..n_reqs)
            .map(|_| coord.submit("m", img.clone()).unwrap())
            .collect();
        for h in handles {
            let _ = h.wait().unwrap();
        }
        let s = t.elapsed_s();
        println!(
            "  max_batch {max_batch:>2}: {n_reqs} reqs in {:.3}s = {:.0} req/s (mean batch {:.1})",
            s,
            n_reqs as f64 / s,
            coord
                .metrics
                .snapshot("m")
                .map(|m| m.mean_batch)
                .unwrap_or(0.0)
        );
    }
    println!();
}

fn save(name: &str, table: &BenchTable) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(format!("{name}.tsv")), table.tsv());
}

/// Spec builder helper kept for future ablations.
#[allow(dead_code)]
fn tiny_spec() -> ModelSpec {
    ModelSpec {
        name: "tiny".into(),
        input_shape: Shape::vector(16),
        input_kind: InputKind::Bytes,
        layers: vec![],
    }
}
