//! **Figure-style sweeps** (extension beyond the paper's tables): how the
//! binary speedup scales with layer width and batch size. The paper
//! reports three spot measurements; these series show the regimes — the
//! binary kernel's advantage grows with width (packing amortizes; float
//! becomes bandwidth-bound) and the batched GEMM amortizes weight sweeps.
//!
//! Emits TSV series to `bench_results/fig_*.tsv` for plotting.

use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::tensor::{Shape, Tensor};
use espresso::util::bench::{bench, BenchConfig};
use espresso::util::rng::Rng;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    width_sweep(quick);
    batch_sweep(quick);
}

fn cfg(quick: bool) -> BenchConfig {
    BenchConfig {
        warmup_iters: 2,
        min_iters: if quick { 3 } else { 8 },
        max_iters: if quick { 5 } else { 30 },
        measure_time: std::time::Duration::from_secs(if quick { 1 } else { 5 }),
    }
}

/// Forward latency vs hidden width, float vs binary (batch 1).
fn width_sweep(quick: bool) {
    println!("== FIG-W: BMLP batch-1 latency vs hidden width ==");
    let widths: &[usize] = if quick {
        &[128, 512, 1024]
    } else {
        &[128, 256, 512, 1024, 2048, 4096]
    };
    let c = cfg(quick);
    let mut tsv = String::from("hidden\tfloat_ns\tbinary_ns\tspeedup\n");
    println!("{:>8} {:>12} {:>12} {:>9}", "hidden", "float", "binary", "speedup");
    for &hsize in widths {
        let mut rng = Rng::new(31);
        let spec = bmlp_spec(&mut rng, hsize, 3);
        let nf = Network::<u64>::from_spec(&spec, Backend::Float).unwrap();
        let nb = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
        let img: Vec<u8> = (0..784).map(|_| rng.next_u32() as u8).collect();
        let img = Tensor::from_vec(Shape::vector(784), img);
        let rf = bench("float", &c, || {
            let _ = nf.predict_bytes(&img);
        });
        let rb = bench("binary", &c, || {
            let _ = nb.predict_bytes(&img);
        });
        let speedup = rf.mean_ns() / rb.mean_ns();
        println!(
            "{:>8} {:>12} {:>12} {:>8.1}x",
            hsize,
            espresso::util::stats::fmt_ns(rf.mean_ns()),
            espresso::util::stats::fmt_ns(rb.mean_ns()),
            speedup
        );
        tsv.push_str(&format!(
            "{hsize}\t{:.0}\t{:.0}\t{:.3}\n",
            rf.mean_ns(),
            rb.mean_ns(),
            speedup
        ));
    }
    save("fig_width_sweep", &tsv);
    println!("(speedup grows with width: packing amortizes, float goes bandwidth-bound)\n");
}

/// Per-image latency vs batch size for the batched binary GEMM.
fn batch_sweep(quick: bool) {
    println!("== FIG-B: batched binary GEMM amortization (BMLP, per-image time) ==");
    let hsize = if quick { 512 } else { 2048 };
    let batches: &[usize] = &[1, 2, 4, 8, 16, 32];
    let c = cfg(quick);
    let mut rng = Rng::new(32);
    let spec = bmlp_spec(&mut rng, hsize, 3);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    let mut tsv = String::from("batch\tper_image_ns\n");
    println!("{:>6} {:>14}", "batch", "per-image");
    for &b in batches {
        let data: Vec<u8> = (0..b * 784).map(|_| rng.next_u32() as u8).collect();
        let t = Tensor::from_vec(
            Shape {
                m: b,
                n: 784,
                l: 1,
            },
            data,
        );
        let r = bench(&format!("batch{b}"), &c, || {
            let _ = net.forward(espresso::layers::Act::Bytes(t.clone()));
        });
        let per = r.mean_ns() / b as f64;
        println!("{:>6} {:>14}", b, espresso::util::stats::fmt_ns(per));
        tsv.push_str(&format!("{b}\t{per:.0}\n"));
    }
    save("fig_batch_sweep", &tsv);
    println!();
}

fn save(name: &str, tsv: &str) {
    let dir = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(format!("{name}.tsv")), tsv);
}
