//! **T3 — Table 3: binary CNN on CIFAR-10, batch 1.**
//!
//! Paper (GTX 960): Espresso CPU 85.2 ms | GPU 5.2 ms (16×) | GPU^opt
//! 1.0 ms (85×). Memory (M2): 53.54 MB float → 1.73 MB packed (≈31×).
//!
//! No public binary-conv implementation existed to compare against
//! (§6.3) — the comparison is Espresso's own float path vs its
//! binary-optimized path, which is exactly what this harness measures on
//! the CPU substrate (plus the XLA float engine when its artifact is
//! present).

use espresso::layers::Backend;
use espresso::net::{bcnn_spec, Network};
use espresso::runtime::{artifact_exists, Engine, NativeEngine, XlaEngine, XlaModelKind};
use espresso::tensor::{Shape, Tensor};
use espresso::util::bench::{bench, BenchConfig, BenchTable};
use espresso::util::rng::Rng;
use std::path::Path;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let width: f32 = std::env::var("ESPRESSO_T3_WIDTH")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 0.25 } else { 1.0 });
    println!("== T3: BCNN CIFAR arch width={width}, batch 1 (paper Table 3) ==");
    let mut rng = Rng::new(3);
    let spec = bcnn_spec(&mut rng, width);
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u32() as u8).collect();
    let img = Tensor::from_vec(Shape::new(32, 32, 3), img);

    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: if quick { 3 } else { 5 },
        max_iters: if quick { 5 } else { 30 },
        measure_time: std::time::Duration::from_secs(if quick { 3 } else { 15 }),
    };

    let mut table = BenchTable::new("T3 BCNN batch-1 prediction").baseline("espresso float (CPU comparator)");

    let float = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Float).unwrap(),
        "float",
    );
    table.push(bench("espresso float (CPU comparator)", &cfg, || {
        let _ = float.predict(&img).unwrap();
    }));

    let dir = Path::new("artifacts");
    let artifact = if (width - 1.0).abs() < 1e-6 {
        "bcnn_float"
    } else {
        "bcnn_float_small"
    };
    let arch_matches = (width - 1.0).abs() < 1e-6 || (width - 0.125).abs() < 1e-6;
    if arch_matches && artifact_exists(dir, artifact) {
        match XlaEngine::load(dir, artifact, &spec, XlaModelKind::CnnFloat) {
            Ok(e) => table.push(bench("espresso xla-float (accel analogue)", &cfg, || {
                let _ = e.predict(&img).unwrap();
            })),
            Err(err) => println!("  (xla row skipped: {err})"),
        }
    } else {
        println!("  (xla row needs matching artifact: `make artifacts-full` for width=1.0)");
    }

    let opt = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
        "opt",
    );
    table.push(bench("espresso opt (binary conv, prepacked)", &cfg, || {
        let _ = opt.predict(&img).unwrap();
    }));

    println!("{}", table.render());
    println!("paper: CPU 85.2ms | GPU 5.2ms (16x) | GPU^opt 1.0ms (85x)");

    let rep = opt.net.memory_report();
    println!(
        "\nM2 memory: float {:.2} MB -> packed {:.2} MB ({:.1}x; paper: 53.54 -> 1.73 MB, ~31x)",
        rep.total_float() as f64 / 1e6,
        rep.total_packed() as f64 / 1e6,
        rep.saving()
    );

    let dirp = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dirp);
    let _ = std::fs::write(dirp.join("t3_cnn.tsv"), table.tsv());
}
