//! **T3 — Table 3: binary CNN, batch 1 + batched serving sweep.**
//!
//! Paper (GTX 960, CIFAR BCNN): Espresso CPU 85.2 ms | GPU 5.2 ms (16×) |
//! GPU^opt 1.0 ms (85×). Memory (M2): 53.54 MB float → 1.73 MB packed
//! (≈31×).
//!
//! No public binary-conv implementation existed to compare against
//! (§6.3) — the comparison is Espresso's own float path vs its
//! binary-optimized path, which is exactly what this harness measures on
//! the CPU substrate (plus the XLA float engine when its artifact is
//! present).
//!
//! **Batch sweep (serving extension).** The second table measures the
//! batched CNN forward on the MNIST CNN arch at B ∈ {1, 4, 16, 64}:
//! stacked unrolled patch matrices share one binary GEMM per layer, so
//! per-image latency must FALL as B grows — the GEMM-level dividend the
//! coordinator's dynamic batcher banks on. Emits
//! `bench_results/t3_batch_sweep.tsv`.
//!
//! **Fused vs materialized (ISSUE 3).** The third table compares the
//! fused tile-streaming conv pipeline against the retained materializing
//! oracle at B ∈ {1, 16, 64}: per-image latency plus the per-forward
//! peak-scratch-bytes column for both paths (from the exact `ScratchSpec`
//! reservations `Network::reserve` uses).
//!
//! **Representation sweep (ISSUE 9).** The fourth table retargets the
//! same CNN arch to each activation representation — float comparator,
//! plain binary, scaled binary (XNOR-Net α), ternary (2 planes), 2-bit
//! (3 planes) — and measures per-image latency: P thermometer planes
//! cost P popcount GEMMs, scaled rows add only a float epilogue.
//!
//! All three result sets land in `BENCH_t3.json`.

use espresso::layers::Backend;
use espresso::net::{bcnn_spec, mnist_cnn_spec, Network};
use espresso::runtime::{artifact_exists, Engine, NativeEngine, XlaEngine, XlaModelKind};
use espresso::tensor::{Shape, Tensor};
use espresso::util::bench::{bench, BenchConfig, BenchTable};
use espresso::util::rng::Rng;
use std::path::Path;

fn main() {
    let quick = std::env::var("ESPRESSO_BENCH_QUICK").as_deref() == Ok("1");
    let width: f32 = std::env::var("ESPRESSO_T3_WIDTH")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 0.25 } else { 1.0 });
    println!("== T3: BCNN CIFAR arch width={width}, batch 1 (paper Table 3) ==");
    let mut rng = Rng::new(3);
    let spec = bcnn_spec(&mut rng, width);
    let img: Vec<u8> = (0..32 * 32 * 3).map(|_| rng.next_u32() as u8).collect();
    let img = Tensor::from_vec(Shape::new(32, 32, 3), img);

    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: if quick { 3 } else { 5 },
        max_iters: if quick { 5 } else { 30 },
        measure_time: std::time::Duration::from_secs(if quick { 3 } else { 15 }),
    };

    let mut table = BenchTable::new("T3 BCNN batch-1 prediction").baseline("espresso float (CPU comparator)");

    let float = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Float).unwrap(),
        "float",
    );
    table.push(bench("espresso float (CPU comparator)", &cfg, || {
        let _ = float.predict(&img).unwrap();
    }));

    let dir = Path::new("artifacts");
    let artifact = if (width - 1.0).abs() < 1e-6 {
        "bcnn_float"
    } else {
        "bcnn_float_small"
    };
    let arch_matches = (width - 1.0).abs() < 1e-6 || (width - 0.125).abs() < 1e-6;
    if arch_matches && artifact_exists(dir, artifact) {
        match XlaEngine::load(dir, artifact, &spec, XlaModelKind::CnnFloat) {
            Ok(e) => table.push(bench("espresso xla-float (accel analogue)", &cfg, || {
                let _ = e.predict(&img).unwrap();
            })),
            Err(err) => println!("  (xla row skipped: {err})"),
        }
    } else {
        println!("  (xla row needs matching artifact: `make artifacts-full` for width=1.0)");
    }

    let opt = NativeEngine::new(
        Network::<u64>::from_spec(&spec, Backend::Binary).unwrap(),
        "opt",
    );
    opt.net.reserve(1);
    table.push(bench("espresso opt (binary conv, plan executor)", &cfg, || {
        let _ = opt.predict(&img).unwrap();
    }));

    // the pre-plan execution path: clone the input + walk the layer list
    // re-deciding representations per call. The plan row above must be no
    // slower than this row.
    table.push(bench("espresso opt (legacy layer-walk)", &cfg, || {
        use espresso::layers::Act;
        let _ = opt
            .net
            .forward_layerwalk(Act::Bytes(img.clone()))
            .into_float();
    }));

    println!("{}", table.render());
    println!("paper: CPU 85.2ms | GPU 5.2ms (16x) | GPU^opt 1.0ms (85x)");

    println!("\n== per-layer plan profile (batch-1 measurement run) ==");
    print!("{}", opt.net.profile().render());

    let rep = opt.net.memory_report();
    println!(
        "\nM2 memory: float {:.2} MB -> packed {:.2} MB ({:.1}x; paper: 53.54 -> 1.73 MB, ~31x)",
        rep.total_float() as f64 / 1e6,
        rep.total_packed() as f64 / 1e6,
        rep.saving()
    );

    let dirp = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dirp);
    let _ = std::fs::write(dirp.join("t3_cnn.tsv"), table.tsv());

    batch_sweep(quick);
}

/// Per-image latency of the batched binary CNN forward vs batch size.
fn batch_sweep(quick: bool) {
    let cnn_width = if quick { 0.5 } else { 1.0 };
    println!("\n== T3-B: batched CNN forward, MNIST CNN arch (width={cnn_width}), per-image time ==");
    let mut rng = Rng::new(4);
    let spec = mnist_cnn_spec(&mut rng, cnn_width);
    let net = Network::<u64>::from_spec(&spec, Backend::Binary).unwrap();
    // pick micro-kernels once up front: the sweep then measures the tuned
    // configuration, and the choices land in the BENCH_t3.json kernel list
    net.tune();
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: if quick { 2 } else { 5 },
        max_iters: if quick { 4 } else { 20 },
        measure_time: std::time::Duration::from_secs(if quick { 2 } else { 8 }),
    };
    let imgs: Vec<Tensor<u8>> = (0..64)
        .map(|_| {
            Tensor::from_vec(
                Shape::new(28, 28, 1),
                (0..28 * 28).map(|_| rng.next_u32() as u8).collect(),
            )
        })
        .collect();
    let mut tsv = String::from("batch\tper_image_ns\tspeedup_vs_b1\n");
    let mut per_b1 = f64::NAN;
    println!("{:>6} {:>14} {:>10}", "batch", "per-image", "vs B=1");
    for &b in &[1usize, 4, 16, 64] {
        // plan-time reservation: steady-state sweep iterations never
        // touch the heap for scratch
        net.reserve(b);
        let refs: Vec<&Tensor<u8>> = imgs[..b].iter().collect();
        let r = bench(&format!("batch{b}"), &cfg, || {
            let _ = net.predict_batch_bytes(&refs);
        });
        let per = r.mean_ns() / b as f64;
        if b == 1 {
            per_b1 = per;
        }
        let speedup = per_b1 / per;
        println!(
            "{:>6} {:>14} {:>9.2}x",
            b,
            espresso::util::stats::fmt_ns(per),
            speedup
        );
        tsv.push_str(&format!("{b}\t{per:.0}\t{speedup:.3}\n"));
    }
    println!("(per-image latency falls with B: stacked unrolled rows share one binary GEMM per layer)");
    let dirp = std::path::Path::new("bench_results");
    let _ = std::fs::create_dir_all(dirp);
    let _ = std::fs::write(dirp.join("t3_batch_sweep.tsv"), tsv);

    let (fm_rows, kernels) = fused_vs_materialized(quick, &net, &imgs, &cfg);
    let repr_rows = representation_sweep(quick, &cfg);
    write_t3_json(&net, &fm_rows, &kernels, &repr_rows);
}

/// Fused tile-streaming conv vs the materialized oracle: per-image time
/// and per-forward peak scratch bytes at B ∈ {1, 16, 64}. Returns the
/// JSON row fragments plus the tuned per-step kernel list for
/// `write_t3_json`.
fn fused_vs_materialized(
    quick: bool,
    net: &Network<u64>,
    imgs: &[Tensor<u8>],
    cfg: &espresso::util::bench::BenchConfig,
) -> (Vec<String>, Vec<String>) {
    use espresso::layers::Act;
    println!("\n== T3-C: fused tile-streaming conv vs materialized patch matrix ==");
    println!(
        "{:>6} {:>14} {:>14} {:>8} {:>14} {:>14} {:>8}",
        "batch", "fused/img", "mat/img", "speedup", "scratch", "scratch-mat", "shrink"
    );
    let batches: &[usize] = if quick { &[1, 16] } else { &[1, 16, 64] };
    let mut rows = Vec::new();
    for &b in batches {
        net.reserve(b);
        let refs: Vec<&Tensor<u8>> = imgs[..b].iter().collect();
        let fused = bench(&format!("fused-b{b}"), cfg, || {
            let _ = net.predict_batch_bytes(&refs);
        });
        let stacked = Tensor::stack(&refs);
        let mat = bench(&format!("materialized-b{b}"), cfg, || {
            let _ = net
                .forward_materialized(Act::Bytes(stacked.clone()))
                .into_float();
        });
        let report = net.scratch_report(b);
        let peak_fused = report.iter().map(|r| r.1).max().unwrap_or(0);
        let peak_mat = report.iter().map(|r| r.2).max().unwrap_or(0);
        let fused_per = fused.mean_ns() / b as f64;
        let mat_per = mat.mean_ns() / b as f64;
        println!(
            "{:>6} {:>14} {:>14} {:>7.2}x {:>14} {:>14} {:>7.1}x",
            b,
            espresso::util::stats::fmt_ns(fused_per),
            espresso::util::stats::fmt_ns(mat_per),
            mat_per / fused_per,
            espresso::util::stats::fmt_bytes(peak_fused),
            espresso::util::stats::fmt_bytes(peak_mat),
            peak_mat as f64 / peak_fused.max(1) as f64
        );
        rows.push(format!(
            "    {{\"batch\": {b}, \"fused_ns_per_image\": {fused_per:.0}, \
             \"materialized_ns_per_image\": {mat_per:.0}, \
             \"peak_scratch_fused_bytes\": {peak_fused}, \
             \"peak_scratch_materialized_bytes\": {peak_mat}, \
             \"scratch_reduction\": {:.2}}}",
            peak_mat as f64 / peak_fused.max(1) as f64
        ));
    }
    // per-step kernel choices (written by `net.tune()` in the sweep above)
    let kernels: Vec<String> = net
        .plan()
        .steps
        .iter()
        .map(|s| {
            let (kernel, tile_rows) = s
                .kernel
                .get()
                .map_or_else(|| ("-".to_string(), 0), |c| (c.to_string(), c.tile_rows));
            format!(
                "    {{\"step\": \"{}\", \"kernel\": \"{kernel}\", \"tile_rows\": {tile_rows}}}",
                s.name
            )
        })
        .collect();
    println!("(fused path must not regress throughput; scratch shrink ≥ 4x at B=64 is the ISSUE 3 bar)");
    (rows, kernels)
}

/// Per-representation forward latency: the same CNN arch retargeted to
/// each activation representation via `retarget_repr`, float comparator
/// included. All binary rows run the same tuned popcount kernels and
/// plan executor — only the pack tails and scale epilogues differ, so
/// the column isolates the representation cost itself.
fn representation_sweep(quick: bool, cfg: &BenchConfig) -> Vec<String> {
    use espresso::layers::OutRepr;
    use espresso::net::retarget_repr;
    let width = if quick { 0.25 } else { 0.5 };
    println!(
        "\n== T3-D: activation-representation sweep, MNIST CNN arch (width={width}), per-image time =="
    );
    let mut rng = Rng::new(5);
    let base = mnist_cnn_spec(&mut rng, width);
    let b = if quick { 4usize } else { 16 };
    let imgs: Vec<Tensor<u8>> = (0..b)
        .map(|_| {
            Tensor::from_vec(
                Shape::new(28, 28, 1),
                (0..28 * 28).map(|_| rng.next_u32() as u8).collect(),
            )
        })
        .collect();
    let refs: Vec<&Tensor<u8>> = imgs.iter().collect();
    let variants: [(&str, Backend, Option<(OutRepr, f32)>); 5] = [
        ("float", Backend::Float, None),
        ("binary", Backend::Binary, None),
        ("scaled-binary", Backend::Binary, Some((OutRepr::ScaledSign, 1.0))),
        ("ternary", Backend::Binary, Some((OutRepr::Ternary, 0.75))),
        ("2-bit", Backend::Binary, Some((OutRepr::Quant2, 0.5))),
    ];
    println!(
        "{:>14} {:>8} {:>14} {:>10}",
        "repr", "planes", "per-image", "vs float"
    );
    let mut float_per = f64::NAN;
    let mut rows = Vec::new();
    for (name, backend, retarget) in variants {
        let mut spec = base.clone();
        if let Some((repr, delta)) = retarget {
            retarget_repr(&mut spec, &mut rng, repr, delta, true);
        }
        // activation planes the next layer's GEMM consumes (0 = float)
        let planes = match (backend, retarget) {
            (Backend::Float, _) => 0,
            (_, None) => 1,
            (_, Some((r, _))) => r.planes(),
        };
        let net = Network::<u64>::from_spec(&spec, backend).unwrap();
        net.tune();
        net.reserve(b);
        let r = bench(&format!("repr-{name}"), cfg, || {
            let _ = net.predict_batch_bytes(&refs);
        });
        let per = r.mean_ns() / b as f64;
        if float_per.is_nan() {
            float_per = per;
        }
        let speedup = float_per / per;
        println!(
            "{:>14} {:>8} {:>14} {:>9.2}x",
            name,
            planes,
            espresso::util::stats::fmt_ns(per),
            speedup
        );
        rows.push(format!(
            "    {{\"repr\": \"{name}\", \"planes\": {planes}, \
             \"ns_per_image\": {per:.0}, \"speedup_vs_float\": {speedup:.3}}}"
        ));
    }
    println!("(P thermometer planes cost P popcount GEMMs; scaled rows add only the float epilogue)");
    rows
}

/// Compose `BENCH_t3.json` from the fused-vs-materialized rows, the
/// tuned kernel choices and the representation sweep.
fn write_t3_json(net: &Network<u64>, fm_rows: &[String], kernels: &[String], repr_rows: &[String]) {
    let json = format!(
        "{{\n  \"bench\": \"t3_fused_vs_materialized\",\n  \"arch\": \"{}\",\n  \
         \"simd_level\": \"{}\",\n  \"rows\": [\n{}\n  ],\n  \"kernels\": [\n{}\n  ],\n  \
         \"representations\": [\n{}\n  ]\n}}\n",
        net.name,
        espresso::bitpack::simd::level_name(espresso::bitpack::simd::level()),
        fm_rows.join(",\n"),
        kernels.join(",\n"),
        repr_rows.join(",\n")
    );
    // package root and workspace root (whichever the driver inspects)
    let _ = std::fs::write("BENCH_t3.json", &json);
    let _ = std::fs::write("../BENCH_t3.json", &json);
}
