//! Offline stub of the PJRT/XLA binding crate.
//!
//! The real `xla` crate links a PJRT C-API plugin and is only available in
//! environments with the accelerator toolchain installed. This stub keeps
//! the `runtime::XlaEngine` code path compiling in the offline build while
//! failing *gracefully and loudly* at runtime: `PjRtClient::cpu()` returns
//! an error, so engine loading reports "XLA runtime unavailable" instead
//! of executing garbage. All XLA integration tests and bench rows guard on
//! artifact presence (`artifact_exists`), which is always false without
//! the Python AOT toolchain, so they skip cleanly.

use std::fmt;

/// Error type mirroring the binding crate's: displayable, nothing more.
pub struct Error(String);

impl Error {
    fn stub() -> Self {
        Error("XLA runtime unavailable in this build (offline stub)".to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// PJRT client handle (unconstructable through the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the offline build.
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::stub())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::stub())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(Error::stub())
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(Error::stub())
    }
}

/// A computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub())
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::stub())
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::stub())
    }
}

/// A host literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal { _private: () }
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Self, Error> {
        Err(Error::stub())
    }

    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::stub())
    }
}
