//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this shim carries
//! exactly the surface the workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait for `Result` and `Option`.
//!
//! Differences from the real crate (none observable to our callers):
//! errors are flattened to a single message string at construction
//! (context is prepended as `"context: cause"`), there is no backtrace
//! capture, and no downcasting.

use std::fmt;

/// A flattened error value: a message chain rendered eagerly.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context layer (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent (same as real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/espresso")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        assert!(f(0).unwrap_err().to_string().contains("zero"));
    }
}
