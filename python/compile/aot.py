"""AOT bridge: lower the L2 models to HLO **text** for the Rust runtime.

HLO text (not a serialized ``HloModuleProto``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Each artifact gets a ``.meta`` sidecar listing the exact parameter order,
dtypes and shapes the compiled executable expects; the Rust runtime
validates its literal list against it at load time.

Run: ``python -m compile.aot --out-dir ../artifacts [--full]``
(The paper-size BMLP/BCNN artifacts are large and slow to lower; the
default set covers the trained/small arches plus a smoke module, and
``--full`` adds the paper-size ones used by the XLA-engine benches.)
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_name(dt) -> str:
    return np.dtype(dt).name


def lower_fn(fn, arg_specs):
    lowered = jax.jit(fn).lower(*[_spec(s, d) for (s, d) in arg_specs])
    return to_hlo_text(lowered)


def write_artifact(out_dir: str, name: str, fn, arg_specs) -> None:
    text = lower_fn(fn, arg_specs)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    meta = os.path.join(out_dir, f"{name}.meta")
    with open(meta, "w") as f:
        f.write(f"artifact {name}\n")
        f.write(f"args {len(arg_specs)}\n")
        for (shape, dtype) in arg_specs:
            dims = ",".join(str(d) for d in shape) if shape else "scalar"
            f.write(f"arg {_dtype_name(dtype)} {dims}\n")
    print(f"wrote {path} ({len(text) / 1e6:.2f} MB text, {len(arg_specs)} args)")


# ---------------------------------------------------------------------
# artifact builders
# ---------------------------------------------------------------------


def bmlp_float_artifact(arch: M.MlpArch):
    specs = M.bmlp_float_param_specs(arch)
    arg_specs = [(s, d) for (s, d) in specs] + [((arch.in_features,), jnp.float32)]

    def fn(*args):
        params, x = list(args[:-1]), args[-1]
        return (M.bmlp_float_forward(arch, params, x),)

    return fn, arg_specs


def bmlp_binary_artifact(arch: M.MlpArch):
    specs = M.bmlp_binary_param_specs(arch)
    arg_specs = [(s, d) for (s, d) in specs] + [((arch.in_features,), jnp.uint8)]

    def fn(*args):
        params, x = list(args[:-1]), args[-1]
        return (M.bmlp_binary_forward(arch, params, x),)

    return fn, arg_specs


def bcnn_float_artifact(arch: M.CnnArch):
    specs = M.bcnn_float_param_specs(arch)
    arg_specs = [(s, d) for (s, d) in specs] + [
        ((arch.height, arch.width, arch.in_channels), jnp.float32)
    ]

    def fn(*args):
        params, x = list(args[:-1]), args[-1]
        return (M.bcnn_float_forward(arch, params, x),)

    return fn, arg_specs


def bcnn_binary_artifact(arch: M.CnnArch):
    specs = M.bcnn_binary_param_specs(arch)
    arg_specs = [(s, d) for (s, d) in specs] + [
        ((arch.height, arch.width, arch.in_channels), jnp.uint8)
    ]

    def fn(*args):
        params, x = list(args[:-1]), args[-1]
        return (M.bcnn_binary_forward(arch, params, x),)

    return fn, arg_specs


def smoke_artifact():
    """Tiny matmul+2 module for fast runtime sanity tests."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    return fn, [((2, 2), jnp.float32), ((2, 2), jnp.float32)]


# the small arches must match rust tests / the trained model
SMALL_MLP = M.MlpArch(hidden=256, hidden_layers=2)
SMALL_CNN = M.CnnArch(stage_channels=(16, 32, 64), fc=128)
# packed CNN needs 32-divisible stages (see bcnn_binary_forward)
SMALL_CNN_BIN = M.CnnArch(stage_channels=(32, 32, 64), fc=128)
PAPER_MLP = M.MlpArch()  # 3 x 4096
PAPER_CNN = M.CnnArch()  # 128/256/512 + 1024 FC


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--full",
        action="store_true",
        help="also lower the paper-size BMLP/BCNN (slow, large artifacts)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    write_artifact(args.out_dir, "smoke", *smoke_artifact())
    write_artifact(args.out_dir, "bmlp_float_small", *bmlp_float_artifact(SMALL_MLP))
    write_artifact(args.out_dir, "bmlp_binary_small", *bmlp_binary_artifact(SMALL_MLP))
    write_artifact(args.out_dir, "bcnn_float_small", *bcnn_float_artifact(SMALL_CNN))
    write_artifact(
        args.out_dir, "bcnn_binary_small", *bcnn_binary_artifact(SMALL_CNN_BIN)
    )
    if args.full:
        write_artifact(args.out_dir, "bmlp_float", *bmlp_float_artifact(PAPER_MLP))
        write_artifact(args.out_dir, "bmlp_binary", *bmlp_binary_artifact(PAPER_MLP))
        write_artifact(args.out_dir, "bcnn_float", *bcnn_float_artifact(PAPER_CNN))


if __name__ == "__main__":
    main()
