"""L1 Pallas kernels + packing ops + pure-numpy reference oracles."""

from . import binary_gemm, pack, ref  # noqa: F401
