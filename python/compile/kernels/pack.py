"""Bit-packing ops in JAX (jnp formulations + a Pallas pack kernel).

These are the L1 building blocks the L2 model composes with the Pallas
GEMM: sign-packing activations into uint32 lanes, threshold-packing the
folded BN+sign, and bit-plane decomposition of fixed-precision inputs
(paper §4.1–§4.3). The jnp formulations lower into the same fused HLO as
the GEMM kernel; `pack_sign_pallas` exists to exercise packing *as* a
Pallas kernel as well.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD = 32


def words_for(k: int) -> int:
    return (k + WORD - 1) // WORD


def _lane_weights() -> jnp.ndarray:
    return (jnp.uint32(1) << jnp.arange(WORD, dtype=jnp.uint32)).astype(jnp.uint32)


def pack_sign(x: jnp.ndarray) -> jnp.ndarray:
    """Pack the last axis: bit = (x >= 0). Output uint32 (..., kw)."""
    k = x.shape[-1]
    kw = words_for(k)
    bits = (x >= 0).astype(jnp.uint32)
    pad = kw * WORD - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    lanes = bits.reshape(bits.shape[:-1] + (kw, WORD))
    return (lanes * _lane_weights()).sum(axis=-1).astype(jnp.uint32)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a {0,1} integer array along the last axis into uint32 words."""
    k = bits.shape[-1]
    kw = words_for(k)
    bits = bits.astype(jnp.uint32)
    pad = kw * WORD - k
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    lanes = bits.reshape(bits.shape[:-1] + (kw, WORD))
    return (lanes * _lane_weights()).sum(axis=-1).astype(jnp.uint32)


def unpack_pm1(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Unpack uint32 words to ±1 float32 of logical length k."""
    kw = words.shape[-1]
    lanes = (words[..., :, None] >> jnp.arange(WORD, dtype=jnp.uint32)) & 1
    flat = lanes.reshape(words.shape[:-1] + (kw * WORD,))[..., :k]
    return jnp.where(flat == 1, 1.0, -1.0).astype(jnp.float32)


def threshold_pack(x: jnp.ndarray, tau: jnp.ndarray, gamma_pos: jnp.ndarray) -> jnp.ndarray:
    """Folded BN+sign then pack: bit = (x>=tau) if gamma_pos else (x<=tau).

    `x` int32/float (..., n); `tau` float (n,); `gamma_pos` float mask (n,)
    with 1.0 = positive gamma.
    """
    xf = x.astype(jnp.float32)
    bit = jnp.where(gamma_pos > 0.5, xf >= tau, xf <= tau)
    return pack_bits(bit.astype(jnp.uint32))


def bitplane_decompose(x_u8: jnp.ndarray) -> jnp.ndarray:
    """8 packed bit-planes of a uint8 vector: (8, kw) uint32."""
    x = x_u8.astype(jnp.uint32)
    planes = (x[None, :] >> jnp.arange(8, dtype=jnp.uint32)[:, None]) & 1
    return pack_bits(planes)


def bitplane_matvec(x_u8: jnp.ndarray, w_packed: jnp.ndarray, k: int) -> jnp.ndarray:
    """First-layer binary-optimized matvec (paper Eq. 3).

    x_u8: (k,) uint8; w_packed: (n, kw) uint32 rows. Returns int32 (n,)
    equal to the integer dot of pixels against ±1 weights.
    """
    planes = bitplane_decompose(x_u8)  # (8, kw)
    pos = jax.lax.population_count(planes[:, None, :] & w_packed[None, :, :])
    neg = jax.lax.population_count(planes[:, None, :] & ~w_packed[None, :, :])
    # mask out padding bits beyond k: they are 0 in planes, so already fine
    pd = (pos.astype(jnp.int32) - neg.astype(jnp.int32)).sum(axis=-1)  # (8, n)
    scale = (jnp.int32(1) << jnp.arange(8, dtype=jnp.int32))[:, None]
    return (pd * scale).sum(axis=0).astype(jnp.int32)


# ---------------------------------------------------------------------
# Pallas pack kernel
# ---------------------------------------------------------------------

def _pack_kernel(x_ref, o_ref):
    """One grid row: pack (bm, kw*32) floats into (bm, kw) words."""
    x = x_ref[...]
    bits = (x >= 0).astype(jnp.uint32)
    lanes = bits.reshape(bits.shape[0], -1, WORD)
    o_ref[...] = (lanes * _lane_weights()).sum(axis=-1).astype(jnp.uint32)


def pack_sign_pallas(x: jnp.ndarray, block_rows: int = 8) -> jnp.ndarray:
    """Pallas version of pack_sign for 2-D inputs (m, k); k must be a
    multiple of 32 (pad upstream). interpret=True: CPU-runnable HLO."""
    m, k = x.shape
    assert k % WORD == 0, "pad k to a word boundary first"
    kw = k // WORD
    bm = min(block_rows, m)
    assert m % bm == 0, "pad m to a block boundary first"
    return pl.pallas_call(
        _pack_kernel,
        out_shape=jax.ShapeDtypeStruct((m, kw), jnp.uint32),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, kw), lambda i: (i, 0)),
        interpret=True,
    )(x)
