"""Pure-numpy reference oracles for the Pallas kernels.

Everything here is deliberately the slow, obviously-correct formulation;
pytest checks the Pallas kernels and the packed model paths against these.
Conventions match the Rust side (DESIGN.md §6): bit 1 ⇔ +1, bit 0 ⇔ -1,
`dot(a,b) = K - 2*popcount(a XOR b)`, sign(0) = +1.
"""

from __future__ import annotations

import numpy as np

WORD = 32  # packing width on the JAX side (uint32 lanes)


def sign_pm1(x: np.ndarray) -> np.ndarray:
    """sign with sign(0) = +1, returning ±1 floats."""
    return np.where(np.asarray(x) >= 0, 1.0, -1.0).astype(np.float32)


def pack_rows(x: np.ndarray) -> np.ndarray:
    """Pack the last axis of a ±1(ish) float array into uint32 words.

    bit i of word w = (x[..., w*32+i] >= 0). Tail bits are zero.
    """
    x = np.asarray(x)
    k = x.shape[-1]
    kw = (k + WORD - 1) // WORD
    bits = (x >= 0).astype(np.uint32)
    padded = np.zeros(x.shape[:-1] + (kw * WORD,), dtype=np.uint32)
    padded[..., :k] = bits
    lanes = padded.reshape(x.shape[:-1] + (kw, WORD))
    weights = (np.uint32(1) << np.arange(WORD, dtype=np.uint32)).astype(np.uint32)
    return (lanes * weights).sum(axis=-1).astype(np.uint32)


def unpack_rows(words: np.ndarray, k: int) -> np.ndarray:
    """Inverse of pack_rows: ±1 floats of logical length k."""
    words = np.asarray(words, dtype=np.uint32)
    kw = words.shape[-1]
    lanes = (words[..., :, None] >> np.arange(WORD, dtype=np.uint32)) & 1
    flat = lanes.reshape(words.shape[:-1] + (kw * WORD,))[..., :k]
    return np.where(flat == 1, 1.0, -1.0).astype(np.float32)


def popcount(x: np.ndarray) -> np.ndarray:
    """Vectorized 32-bit popcount."""
    x = np.asarray(x, dtype=np.uint32)
    x = x - ((x >> 1) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> 2) & np.uint32(0x33333333))
    x = (x + (x >> 4)) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> 24).astype(np.uint32)


def binary_gemm_packed(a: np.ndarray, b: np.ndarray, k_bits: int) -> np.ndarray:
    """Reference packed GEMM: out[m,n] = k - 2*popcount_mismatch(a_m, b_n)."""
    a = np.asarray(a, dtype=np.uint32)
    b = np.asarray(b, dtype=np.uint32)
    mis = popcount(a[:, None, :] ^ b[None, :, :]).sum(axis=-1).astype(np.int64)
    return (k_bits - 2 * mis).astype(np.int32)


def binary_gemm_float(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """±1 GEMM in float: out = sign(a) @ sign(b).T as exact int32."""
    sa = sign_pm1(a)
    sb = sign_pm1(b)
    return (sa @ sb.T).astype(np.int32)


def bitplanes(x_u8: np.ndarray) -> np.ndarray:
    """8 bit-planes of a uint8 vector, shape (8, k) in {0,1}."""
    x = np.asarray(x_u8, dtype=np.uint8)
    return ((x[None, :] >> np.arange(8, dtype=np.uint8)[:, None]) & 1).astype(np.int32)


def bitplane_dot(x_u8: np.ndarray, w_pm1: np.ndarray) -> np.ndarray:
    """First-layer reference: integer pixels against ±1 weight rows.

    out[n] = sum_t x[t] * w[n, t], computed via the paper's Eq. 3
    bit-plane recombination (must equal the direct integer dot).
    """
    planes = bitplanes(x_u8)  # (8, k)
    w = sign_pm1(w_pm1).astype(np.int32)  # (n, k)
    pd = planes @ w.T  # (8, n)
    scale = (1 << np.arange(8, dtype=np.int64))[:, None]
    return (pd * scale).sum(axis=0).astype(np.int32)


def threshold_bits(x: np.ndarray, tau: np.ndarray, gamma_pos: np.ndarray) -> np.ndarray:
    """Folded BN+sign bits: (x >= tau) where gamma_pos else (x <= tau)."""
    x = np.asarray(x, dtype=np.float32)
    return np.where(np.asarray(gamma_pos, bool), x >= tau, x <= tau)


def bn_apply(x, gamma, beta, mean, var, eps):
    sigma = np.sqrt(np.asarray(var) + eps)
    return gamma * (np.asarray(x) - mean) / sigma + beta


def conv2d_ref(x: np.ndarray, w: np.ndarray, pad: int) -> np.ndarray:
    """Direct HWC convolution oracle, stride 1.

    x: (h, w, cin); w: (f, kh, kw, cin); returns (oh, ow, f) float32 with
    zero padding.
    """
    h, wdt, cin = x.shape
    f, kh, kw, cin2 = w.shape
    assert cin == cin2
    oh = h + 2 * pad - kh + 1
    ow = wdt + 2 * pad - kw + 1
    xp = np.zeros((h + 2 * pad, wdt + 2 * pad, cin), dtype=np.float32)
    xp[pad : pad + h, pad : pad + wdt] = x
    out = np.zeros((oh, ow, f), dtype=np.float32)
    for oy in range(oh):
        for ox in range(ow):
            patch = xp[oy : oy + kh, ox : ox + kw]  # (kh,kw,cin)
            out[oy, ox] = np.tensordot(w, patch, axes=([1, 2, 3], [0, 1, 2]))
    return out


def maxpool2d_ref(x: np.ndarray, k: int, stride: int) -> np.ndarray:
    """(h, w, c) max pool."""
    h, w, c = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    out = np.full((oh, ow, c), -np.inf, dtype=np.float32)
    for oy in range(oh):
        for ox in range(ow):
            win = x[oy * stride : oy * stride + k, ox * stride : ox * stride + k]
            out[oy, ox] = win.max(axis=(0, 1))
    return out
