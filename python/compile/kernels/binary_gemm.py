"""Pallas XNOR-popcount GEMM — the paper's compute hot-spot as a TPU-shaped
kernel (§5.2 "Efficient Matrix multiplication", adapted per DESIGN.md
§Hardware-Adaptation).

Operands are bit-packed uint32 matrices: `a` is (m, kw) activation rows,
`b` is (n, kw) weight rows (one row per output neuron, i.e. pre-transposed
— the same layout the Rust engine uses). The kernel computes

    out[i, j] = k_bits - 2 * popcount(a[i] XOR b[j])

with a grid over (m/bm, n/bn) output tiles. Each grid step pulls a
(bm, kw) A-panel and a (bn, kw) B-panel HBM→VMEM via BlockSpec — the
Pallas analogue of the paper's shared-memory tiles — and reduces over the
packed K axis with `lax.population_count` on the VPU's integer lanes.
The K axis is *not* gridded: for the evaluation networks kw ≤ 256 words,
so a full panel pair is ≤ (128+128)×256×4 B = 256 KiB, comfortably inside
a TPU core's ~16 MiB VMEM (the footprint estimate in EXPERIMENTS.md §Perf
is derived from these block shapes).

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO, which is what the AOT
bridge ships to the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref, *, k_bits: int):
    """One (bm, bn) output tile: XOR + popcount + reduce over words."""
    a = a_ref[...]  # (bm, kw) uint32
    b = b_ref[...]  # (bn, kw) uint32
    mis = jax.lax.population_count(a[:, None, :] ^ b[None, :, :])
    mis = mis.astype(jnp.int32).sum(axis=-1)  # (bm, bn)
    o_ref[...] = jnp.int32(k_bits) - 2 * mis


def _pad_rows(x: jnp.ndarray, mult: int) -> jnp.ndarray:
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


@functools.partial(jax.jit, static_argnames=("k_bits", "block_m", "block_n"))
def binary_gemm(
    a: jnp.ndarray,
    b: jnp.ndarray,
    k_bits: int,
    block_m: int = 8,
    block_n: int = 128,
) -> jnp.ndarray:
    """Packed binary GEMM via the Pallas kernel.

    a: (m, kw) uint32, b: (n, kw) uint32 → (m, n) int32. Handles m/n not
    divisible by the block sizes by padding with zero rows (all −1
    vectors) and slicing the result.
    """
    m, kw = a.shape
    n, kw2 = b.shape
    assert kw == kw2, f"word count mismatch {kw} vs {kw2}"
    bm = min(block_m, m) if m > 0 else 1
    bn = min(block_n, n) if n > 0 else 1
    ap = _pad_rows(a, bm)
    bp = _pad_rows(b, bn)
    mp, np_ = ap.shape[0], bp.shape[0]
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, k_bits=k_bits),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def binary_matvec(x: jnp.ndarray, b: jnp.ndarray, k_bits: int) -> jnp.ndarray:
    """Batch-1 convenience wrapper: (kw,) × (n, kw) → (n,) int32."""
    return binary_gemm(x[None, :], b, k_bits)[0]


# VMEM/roofline bookkeeping used by DESIGN.md §Perf -------------------------

def vmem_bytes(block_m: int, block_n: int, kw: int) -> int:
    """Bytes resident in VMEM for one grid step (A panel + B panel + out)."""
    return 4 * (block_m * kw + block_n * kw + block_m * block_n)


def ops_per_grid_step(block_m: int, block_n: int, kw: int) -> int:
    """Integer lane-ops per grid step (xor + popcount + add per word pair)."""
    return 3 * block_m * block_n * kw
