"""Exporters: trained JAX parameters → the Rust-side `.esp` model format,
plus `.espdata` test-set files.

This is the paper's "utility script distributed together with our
sources" (§5.2 *Converting a network to Espresso*): training happens in
the Python world (``train.py``, standing in for BinaryNet), and this
module writes the parameters file the Rust engines load once at startup.

Format mirrors ``rust/src/format/mod.rs`` exactly (little-endian):
magic "ESP1", version, name, input shape/kind, then tagged layers.
`.espdata`: magic "ESPD", version, shape, count, u8 images + u8 labels.
"""

from __future__ import annotations

import struct
from typing import List, Optional, Tuple

import numpy as np

MAGIC = b"ESP1"
DATA_MAGIC = b"ESPD"
VERSION = 1

INPUT_BYTES = 0
INPUT_FLOAT = 1


def _u32(v: int) -> bytes:
    return struct.pack("<I", v)


def _f32(v: float) -> bytes:
    return struct.pack("<f", v)


def _f32s(a) -> bytes:
    a = np.asarray(a, dtype=np.float32).ravel()
    return _u32(a.size) + a.tobytes()


def _bn_bytes(bn: dict) -> bytes:
    return (
        _f32(float(bn["eps"]))
        + _f32s(bn["gamma"])
        + _f32s(bn["beta"])
        + _f32s(bn["mean"])
        + _f32s(bn["var"])
    )


def dense_layer(
    weights: np.ndarray,
    sign: bool,
    bn: Optional[dict] = None,
    bitplane_first: bool = False,
) -> bytes:
    """Dense layer record. weights: (out, in) row-major."""
    out_f, in_f = weights.shape
    flags = int(sign) | (int(bn is not None) << 1) | (int(bitplane_first) << 2)
    body = bytes([1]) + _u32(in_f) + _u32(out_f) + bytes([flags]) + _f32s(weights)
    if bn is not None:
        body += _bn_bytes(bn)
    return body


def conv_layer(
    weights: np.ndarray,
    stride: int,
    pad: int,
    sign: bool,
    pool: Optional[Tuple[int, int]] = None,
    bn: Optional[dict] = None,
    bitplane_first: bool = True,
) -> bytes:
    """Conv layer record. weights: (f, kh, kw, cin)."""
    f, kh, kw, cin = weights.shape
    flags = (
        int(sign)
        | (int(bn is not None) << 1)
        | (int(pool is not None) << 2)
        | (int(bitplane_first) << 3)
    )
    body = bytes([2])
    for v in (cin, f, kh, kw, stride, pad):
        body += _u32(v)
    body += bytes([flags])
    if pool is not None:
        body += _u32(pool[0]) + _u32(pool[1])
    body += _f32s(weights)
    if bn is not None:
        body += _bn_bytes(bn)
    return body


def write_esp(
    path: str,
    name: str,
    input_shape: Tuple[int, int, int],
    input_kind: int,
    layer_records: List[bytes],
) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(_u32(VERSION))
        f.write(_u32(len(name)) + name.encode())
        for d in input_shape:
            f.write(_u32(d))
        f.write(bytes([input_kind]))
        f.write(_u32(len(layer_records)))
        for rec in layer_records:
            f.write(rec)


PIX_SCALE = 127.5  # training normalization: x_norm = x/127.5 - 1


def absorb_input_normalization(w: np.ndarray, bn: dict) -> dict:
    """Rewrite a first-layer BN trained on normalized input
    (x/127.5 − 1) so the exported network consumes RAW uint8 pixels.

    acc_norm = acc_raw/127.5 − s  with s = Σ_t w[j,t], so
    BN(acc_norm) = γ(acc_raw − 127.5(μ+s)) / (127.5σ) + β — i.e. scale
    mean and sigma (var by 127.5², folding eps in first).
    """
    s = np.where(w >= 0, 1.0, -1.0).sum(axis=1).astype(np.float32)
    var_eff = np.asarray(bn["var"], np.float32) + float(bn["eps"])
    return dict(
        gamma=np.asarray(bn["gamma"], np.float32),
        beta=np.asarray(bn["beta"], np.float32),
        mean=(PIX_SCALE * (np.asarray(bn["mean"], np.float32) + s)).astype(np.float32),
        var=(var_eff * PIX_SCALE * PIX_SCALE).astype(np.float32),
        eps=0.0,
    )


def export_mlp(
    path: str,
    name: str,
    layers: List[dict],
    in_shape: Tuple[int, int, int],
    normalized_input: bool = False,
) -> None:
    """Export MLP layer dicts (w, gamma, beta, mean, var, eps) to .esp.

    Hidden layers get sign activations; the output layer keeps scores.
    When ``normalized_input``, the first layer's BN is rewritten so the
    exported model consumes raw uint8 pixels.
    """
    records = []
    n = len(layers)
    for i, l in enumerate(layers):
        bn = {k: l[k] for k in ("gamma", "beta", "mean", "var", "eps")}
        if i == 0 and normalized_input:
            bn = absorb_input_normalization(np.asarray(l["w"], np.float32), bn)
        records.append(
            dense_layer(
                np.asarray(l["w"], np.float32),
                sign=(i < n - 1),
                bn=bn,
                bitplane_first=(i == 0),
            )
        )
    write_esp(path, name, in_shape, INPUT_BYTES, records)


def export_cnn(
    path: str,
    name: str,
    conv_layers: List[dict],
    fc_layers: List[dict],
    in_shape: Tuple[int, int, int],
) -> None:
    """Export CNN layer dicts to .esp (conv: w (f,kh,kw,cin) + pool flag)."""
    records = []
    for l in conv_layers:
        bn = {k: l[k] for k in ("gamma", "beta", "mean", "var", "eps")}
        records.append(
            conv_layer(
                np.asarray(l["w"], np.float32),
                stride=1,
                pad=1,
                sign=True,
                pool=(2, 2) if l.get("pool") else None,
                bn=bn,
            )
        )
    n = len(fc_layers)
    for i, l in enumerate(fc_layers):
        bn = {k: l[k] for k in ("gamma", "beta", "mean", "var", "eps")}
        records.append(
            dense_layer(np.asarray(l["w"], np.float32), sign=(i < n - 1), bn=bn)
        )
    write_esp(path, name, in_shape, INPUT_BYTES, records)


def write_espdata(path: str, images: np.ndarray, labels: np.ndarray, shape) -> None:
    """Test-set file: magic, version, shape (m,n,l), count, images, labels."""
    images = np.asarray(images, dtype=np.uint8)
    labels = np.asarray(labels, dtype=np.uint8)
    count = images.shape[0]
    assert labels.shape[0] == count
    m, n, l = shape
    assert images.reshape(count, -1).shape[1] == m * n * l
    with open(path, "wb") as f:
        f.write(DATA_MAGIC)
        f.write(_u32(VERSION))
        for d in (m, n, l):
            f.write(_u32(d))
        f.write(_u32(count))
        f.write(images.tobytes())
        f.write(labels.tobytes())
