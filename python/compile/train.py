"""Straight-through-estimator training of a binary MLP (paper §4.4).

Stands in for BinaryNet's Theano trainer: float master weights, binary
{−1,+1} weights/activations in the forward pass, straight-through
gradients (identity clipped to |x| ≤ 1), weight clipping to [−1, 1], and
BatchNorm with running statistics. The trained network is exported to
`.esp` (plus an `.espdata` test set) so the Rust engines can demonstrate
real end-to-end classification, not just timing.

Data is the synthetic MNIST-shaped blob dataset (same family as the Rust
generator in `rust/src/data`): per-class Gaussian-bump prototypes, pixel
noise, ±2px jitter — learnable but not trivial.

Run: ``python -m compile.train --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import os
from functools import partial
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import convert

# ---------------------------------------------------------------------
# synthetic dataset (blob prototypes + noise + jitter)
# ---------------------------------------------------------------------


def make_dataset(n: int, seed: int, h: int = 28, w: int = 28, classes: int = 10):
    """Returns (images u8 (n, h*w), labels (n,))."""
    rng = np.random.default_rng(seed)
    protos = []
    for _ in range(classes):
        bumps = rng.integers(4, 7)
        field = np.zeros((h, w), np.float32)
        ys, xs = np.mgrid[0:h, 0:w].astype(np.float32)
        for _ in range(bumps):
            cy = rng.uniform(0.15, 0.85) * h
            cx = rng.uniform(0.15, 0.85) * w
            r = rng.uniform(1.5, 4.0)
            a = rng.uniform(0.6, 1.0)
            field += a * np.exp(-((ys - cy) ** 2 + (xs - cx) ** 2) / (2 * r * r))
        protos.append(np.clip(field, 0, 1))
    images = np.zeros((n, h * w), np.uint8)
    labels = np.zeros(n, np.int64)
    for i in range(n):
        c = i % classes
        dy, dx = rng.integers(-2, 3, size=2)
        shifted = np.roll(np.roll(protos[c], dy, axis=0), dx, axis=1)
        noisy = shifted + rng.uniform(-0.15, 0.15, size=shifted.shape)
        images[i] = (np.clip(noisy, 0, 1) * 255).astype(np.uint8).ravel()
        labels[i] = c
    return images, labels


# ---------------------------------------------------------------------
# STE ops
# ---------------------------------------------------------------------


@jax.custom_vjp
def ste_sign(x):
    return jnp.where(x >= 0, 1.0, -1.0)


def _ste_fwd(x):
    return ste_sign(x), x


def _ste_bwd(x, g):
    # straight-through: pass gradient where |x| <= 1 (paper §4.4)
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_fwd, _ste_bwd)


def init_params(key, dims: List[tuple]):
    params = []
    for i, (fin, fout) in enumerate(dims):
        key, k1 = jax.random.split(key)
        w = jax.random.uniform(k1, (fout, fin), minval=-1.0, maxval=1.0) * 0.5
        params.append(
            dict(
                w=w,
                gamma=jnp.ones(fout),
                beta=jnp.zeros(fout),
            )
        )
    return params


def forward_train(params, x, train: bool, stats=None):
    """Binary forward with batch-stat BN. x: (b, in) normalized floats.

    Returns (logits, batch_stats) where batch_stats are the per-layer
    (mean, var) actually used (for running-average tracking).
    """
    h = x
    used = []
    n = len(params)
    for i, p in enumerate(params):
        wb = ste_sign(p["w"])
        acc = h @ wb.T
        if train:
            mu = acc.mean(axis=0)
            var = acc.var(axis=0) + 1e-4
        else:
            mu, var = stats[i]
        used.append((mu, var))
        y = p["gamma"] * (acc - mu) / jnp.sqrt(var) + p["beta"]
        h = ste_sign(y) if i < n - 1 else y
    return h, used


def loss_fn(params, x, labels):
    logits, used = forward_train(params, x, train=True)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    return loss, used


@partial(jax.jit, static_argnames=())
def train_step(params, opt, x, labels, lr, step):
    """One Adam step with STE gradients and weight clipping (§4.4)."""
    (loss, used), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, x, labels)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step + 1
    new_params = []
    new_opt = []
    for p, g, (m, v) in zip(params, grads, opt):
        nm = {k: b1 * m[k] + (1 - b1) * g[k] for k in p}
        nv = {k: b2 * v[k] + (1 - b2) * g[k] ** 2 for k in p}
        np_ = {}
        for k in p:
            mhat = nm[k] / (1 - b1**t)
            vhat = nv[k] / (1 - b2**t)
            np_[k] = p[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        np_["w"] = jnp.clip(np_["w"], -1.0, 1.0)  # weight clipping (§4.4)
        new_params.append(np_)
        new_opt.append((nm, nv))
    return new_params, new_opt, loss, used


def evaluate(params, stats, x, labels):
    logits, _ = forward_train(params, x, train=False, stats=stats)
    return float((jnp.argmax(logits, axis=1) == labels).mean())


def train_bmlp(
    hidden: int = 256,
    hidden_layers: int = 2,
    n_train: int = 4000,
    n_test: int = 1000,
    epochs: int = 25,
    batch: int = 100,
    lr: float = 0.003,
    seed: int = 7,
    log=print,
):
    """Train; returns (layer dicts for convert.export_mlp, test set, acc)."""
    images, labels = make_dataset(n_train + n_test, seed)
    xtr, ytr = images[:n_train], labels[:n_train]
    xte, yte = images[n_train:], labels[n_train:]
    norm = lambda im: im.astype(np.float32) / convert.PIX_SCALE - 1.0

    dims = []
    prev = 28 * 28
    for _ in range(hidden_layers):
        dims.append((prev, hidden))
        prev = hidden
    dims.append((prev, 10))

    key = jax.random.PRNGKey(seed)
    params = init_params(key, dims)
    opt = [
        (
            {k: jnp.zeros_like(v) for k, v in p.items()},
            {k: jnp.zeros_like(v) for k, v in p.items()},
        )
        for p in params
    ]
    running = [(jnp.zeros(fout), jnp.ones(fout)) for (_, fout) in dims]

    xtr_n = jnp.asarray(norm(xtr))
    ytr_j = jnp.asarray(ytr)
    steps = n_train // batch
    rng = np.random.default_rng(seed)
    gstep = 0
    for epoch in range(epochs):
        perm = rng.permutation(n_train)
        ep_loss = 0.0
        for s in range(steps):
            idx = perm[s * batch : (s + 1) * batch]
            xb = xtr_n[idx]
            yb = ytr_j[idx]
            params, opt, loss, used = train_step(params, opt, xb, yb, lr, gstep)
            gstep += 1
            ep_loss += float(loss)
            running = [
                (0.95 * rm + 0.05 * um, 0.95 * rv + 0.05 * uv)
                for (rm, rv), (um, uv) in zip(running, used)
            ]
        if epoch % 5 == 0 or epoch == epochs - 1:
            acc = evaluate(params, running, jnp.asarray(norm(xte)), jnp.asarray(yte))
            log(f"epoch {epoch:3d}  loss {ep_loss / steps:.4f}  test acc {acc:.3f}")
    acc = evaluate(params, running, jnp.asarray(norm(xte)), jnp.asarray(yte))

    # package layers for export
    layers = []
    for p, (mu, var) in zip(params, running):
        layers.append(
            dict(
                w=np.asarray(jnp.where(p["w"] >= 0, 1.0, -1.0), np.float32),
                gamma=np.asarray(p["gamma"], np.float32),
                beta=np.asarray(p["beta"], np.float32),
                mean=np.asarray(mu, np.float32),
                var=np.asarray(var, np.float32),
                eps=0.0,
            )
        )
    return layers, (xte, yte), acc


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=25)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    layers, (xte, yte), acc = train_bmlp(
        hidden=args.hidden,
        hidden_layers=args.layers,
        epochs=args.epochs,
        seed=args.seed,
    )
    print(f"final binary test accuracy: {acc:.3f}")
    esp = os.path.join(args.out_dir, "bmlp_trained.esp")
    convert.export_mlp(
        esp,
        f"bmlp-trained-{args.hidden}x{args.layers}",
        layers,
        in_shape=(1, 28 * 28, 1),
        normalized_input=True,
    )
    data = os.path.join(args.out_dir, "testset_mnist.espdata")
    convert.write_espdata(data, xte, yte.astype(np.uint8), (1, 28 * 28, 1))
    meta = os.path.join(args.out_dir, "bmlp_trained.acc")
    with open(meta, "w") as f:
        f.write(f"{acc:.4f}\n")
    print(f"wrote {esp}, {data} (acc {acc:.3f})")


if __name__ == "__main__":
    main()
