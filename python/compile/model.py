"""L2: the evaluation networks as JAX forward functions.

Two architectures (paper §6.2/§6.3) in two variants each:

* ``bmlp_float`` / ``bmlp_binary`` — the MNIST MLP (784 → H×L → 10).
  The binary variant is the full Espresso pipeline *inside one HLO
  module*: bit-plane first layer, Pallas XNOR-popcount GEMMs over packed
  weights, folded BN thresholds re-packing activations between layers,
  float affine on the output scores.
* ``bcnn_float`` — the CIFAR-10 VGG-like ConvNet (float comparator; the
  binary conv engine is the Rust native path).

Parameters are flat lists of arrays in a fixed order (documented by
``*_param_specs``); the AOT bridge lowers each forward with those specs
and the Rust runtime feeds literals in the same order. Python never runs
at serving time.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import pack
from .kernels.binary_gemm import binary_gemm

# ---------------------------------------------------------------------
# architecture descriptions
# ---------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpArch:
    """784 → hidden×layers → 10, BinaryNet MNIST shape by default."""

    in_features: int = 784
    hidden: int = 4096
    hidden_layers: int = 3
    classes: int = 10

    @property
    def dims(self) -> List[Tuple[int, int]]:
        dims = []
        prev = self.in_features
        for _ in range(self.hidden_layers):
            dims.append((prev, self.hidden))
            prev = self.hidden
        dims.append((prev, self.classes))
        return dims


@dataclasses.dataclass(frozen=True)
class CnnArch:
    """Hubara-style CIFAR BCNN: (2 conv + pool) × 3 stages + 2 FC + out."""

    height: int = 32
    width: int = 32
    in_channels: int = 3
    stage_channels: Tuple[int, int, int] = (128, 256, 512)
    fc: int = 1024
    classes: int = 10

    @property
    def conv_layers(self):
        """(cin, cout, pool_after) per conv layer."""
        c1, c2, c3 = self.stage_channels
        return [
            (self.in_channels, c1, False),
            (c1, c1, True),
            (c1, c2, False),
            (c2, c2, True),
            (c2, c3, False),
            (c3, c3, True),
        ]

    @property
    def flat(self) -> int:
        return (self.height // 8) * (self.width // 8) * self.stage_channels[2]


# ---------------------------------------------------------------------
# float BMLP
# ---------------------------------------------------------------------


def bmlp_float_param_specs(arch: MlpArch):
    """[(shape, dtype)] per parameter: (w, a, b) per layer.

    BN is pre-folded to an affine `y = a*acc + b` per feature (exact for
    inference); hidden layers then take sign(y).
    """
    specs = []
    for (fin, fout) in arch.dims:
        specs.append(((fout, fin), jnp.float32))  # weights (±1 expected)
        specs.append(((fout,), jnp.float32))  # a
        specs.append(((fout,), jnp.float32))  # b
    return specs


def bmlp_float_forward(arch: MlpArch, params: List[jnp.ndarray], x: jnp.ndarray):
    """x: (in_features,) float32 (raw pixel values). Returns (classes,)."""
    h = x
    n_layers = len(arch.dims)
    for i in range(n_layers):
        w, a, b = params[3 * i : 3 * i + 3]
        acc = jnp.dot(w, h)  # (fout,)
        y = a * acc + b
        if i < n_layers - 1:
            h = jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)
        else:
            h = y
    return h


# ---------------------------------------------------------------------
# binary BMLP (Pallas hot path)
# ---------------------------------------------------------------------


def bmlp_binary_param_specs(arch: MlpArch):
    """Parameter order for the packed model:

    first layer:  w_int8 (h, in), tau (h,), gpos (h,)
    hidden i>0:   w_packed (h, kw) uint32, tau (h,), gpos (h,)
    output:       w_packed (10, kw) uint32, a (10,), b (10,)
    """
    specs = []
    dims = arch.dims
    (fin, fout) = dims[0]
    specs += [((fout, fin), jnp.int8), ((fout,), jnp.float32), ((fout,), jnp.float32)]
    for (fin, fout) in dims[1:-1]:
        specs += [
            ((fout, pack.words_for(fin)), jnp.uint32),
            ((fout,), jnp.float32),
            ((fout,), jnp.float32),
        ]
    (fin, fout) = dims[-1]
    specs += [
        ((fout, pack.words_for(fin)), jnp.uint32),
        ((fout,), jnp.float32),
        ((fout,), jnp.float32),
    ]
    return specs


def bmlp_binary_forward(arch: MlpArch, params: List[jnp.ndarray], x_u8: jnp.ndarray):
    """x_u8: (in_features,) uint8. Returns (classes,) float32 scores.

    Numerically equivalent to ``bmlp_float_forward`` on the same network
    (same thresholds), but running on packed words end to end.
    """
    dims = arch.dims
    # first layer: integer matmul on raw pixels (bit-plane equivalent —
    # XLA computes the same exact int32 accumulators Eq. 3 produces)
    w1, tau1, g1 = params[0:3]
    acc = jnp.dot(w1.astype(jnp.int32), x_u8.astype(jnp.int32))
    bits = pack.threshold_pack(acc[None, :], tau1, g1)  # (1, kw)
    # hidden layers: Pallas packed GEMM + threshold re-pack
    idx = 3
    for (fin, fout) in dims[1:-1]:
        wp, tau, g = params[idx : idx + 3]
        idx += 3
        acc = binary_gemm(bits, wp, fin)  # (1, fout) int32
        bits = pack.threshold_pack(acc, tau, g)
    # output layer: packed GEMM + affine scores
    (fin, fout) = dims[-1]
    wp, a, b = params[idx : idx + 3]
    acc = binary_gemm(bits, wp, fin)[0]
    return a * acc.astype(jnp.float32) + b


# ---------------------------------------------------------------------
# float BCNN
# ---------------------------------------------------------------------


def bcnn_float_param_specs(arch: CnnArch):
    """(w, a, b) per conv layer (w: (f, kh, kw, cin)) then per FC layer."""
    specs = []
    for (cin, cout, _pool) in arch.conv_layers:
        specs.append(((cout, 3, 3, cin), jnp.float32))
        specs.append(((cout,), jnp.float32))
        specs.append(((cout,), jnp.float32))
    dims = [(arch.flat, arch.fc), (arch.fc, arch.fc), (arch.fc, arch.classes)]
    for (fin, fout) in dims:
        specs.append(((fout, fin), jnp.float32))
        specs.append(((fout,), jnp.float32))
        specs.append(((fout,), jnp.float32))
    return specs


def bcnn_float_forward(arch: CnnArch, params: List[jnp.ndarray], x: jnp.ndarray):
    """x: (h, w, cin) float32 raw pixels. Returns (classes,) scores.

    Pipeline per conv block: 3×3 same conv → (2×2 maxpool) → affine BN →
    sign; mirrors the Rust fused ConvLayer (pool on pre-BN accumulators).
    """
    h = x[None, ...]  # NHWC
    idx = 0
    for (cin, cout, pool) in arch.conv_layers:
        w, a, b = params[idx : idx + 3]
        idx += 3
        # w: (f, kh, kw, cin) -> HWIO
        w_hwio = jnp.transpose(w, (1, 2, 3, 0))
        h = jax.lax.conv_general_dilated(
            h,
            w_hwio,
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if pool:
            h = jax.lax.reduce_window(
                h,
                -jnp.inf,
                jax.lax.max,
                window_dimensions=(1, 2, 2, 1),
                window_strides=(1, 2, 2, 1),
                padding="VALID",
            )
        h = a * h + b
        h = jnp.where(h >= 0, 1.0, -1.0).astype(jnp.float32)
    v = h.reshape(-1)
    dims = [(arch.flat, arch.fc), (arch.fc, arch.fc), (arch.fc, arch.classes)]
    for i, (fin, fout) in enumerate(dims):
        w, a, b = params[idx : idx + 3]
        idx += 3
        acc = jnp.dot(w, v)
        y = a * acc + b
        if i < len(dims) - 1:
            v = jnp.where(y >= 0, 1.0, -1.0).astype(jnp.float32)
        else:
            v = y
    return v


# ---------------------------------------------------------------------
# binary BCNN (Pallas packed conv path)
# ---------------------------------------------------------------------


def _unroll_indices(h: int, w: int, kh: int, kw: int, pad: int):
    """Static gather map for im2col: (oh*ow, kh*kw) source-pixel indices,
    with `h*w` standing for the zero (padding) row."""
    import numpy as _np

    oh, ow = h + 2 * pad - kh + 1, w + 2 * pad - kw + 1
    idx = _np.full((oh * ow, kh * kw), h * w, dtype=_np.int32)
    for oy in range(oh):
        for ox in range(ow):
            for ky in range(kh):
                for kx in range(kw):
                    iy, ix = oy + ky - pad, ox + kx - pad
                    if 0 <= iy < h and 0 <= ix < w:
                        idx[oy * ow + ox, ky * kw + kx] = iy * w + ix
    return idx, oh, ow


def bcnn_binary_param_specs(arch: CnnArch):
    """Parameter order for the packed CNN:

    conv 0 (u8 input):  w int8 (f, kh·kw·cin), tau, gpos
    conv i>0:           w_packed (f, kh·kw·cw) uint32, corr (oh·ow, f)
                        int32, tau, gpos
    dense hidden:       w_packed uint32, tau, gpos
    dense out:          w_packed uint32, a, b
    """
    specs = []
    convs = arch.conv_layers
    (cin, cout, _p) = convs[0]
    specs += [
        ((cout, 9 * cin), jnp.int8),
        ((cout,), jnp.float32),
        ((cout,), jnp.float32),
    ]
    h = arch.height
    w = arch.width
    if convs[0][2]:
        h //= 2
        w //= 2
    for (cin, cout, pool) in convs[1:]:
        cw = pack.words_for(cin)
        specs += [
            ((cout, 9 * cw), jnp.uint32),
            ((h * w, cout), jnp.int32),  # zero-padding correction
            ((cout,), jnp.float32),
            ((cout,), jnp.float32),
        ]
        if pool:
            h //= 2
            w //= 2
    dims = [(arch.flat, arch.fc), (arch.fc, arch.fc), (arch.fc, arch.classes)]
    for (fin, fout) in dims:
        specs += [
            ((fout, pack.words_for(fin)), jnp.uint32),
            ((fout,), jnp.float32),
            ((fout,), jnp.float32),
        ]
    return specs


def bcnn_binary_forward(arch: CnnArch, params, x_u8: jnp.ndarray):
    """Packed binary CNN forward (one HLO module, Pallas GEMMs).

    Mirrors the Rust binary engine: first conv in the integer domain
    (exact zero padding), then packed unroll → XNOR-popcount GEMM →
    (+ correction) → int max-pool → threshold pack per conv block;
    packed dense layers; affine scores. x_u8: (h, w, cin) uint8.

    Requires the last conv stage's channel count to be 32-divisible so
    the conv→dense flatten is gap-free in the packed domain (true for
    the paper arch: 512 channels).
    """
    assert arch.stage_channels[2] % 32 == 0, "flatten needs 32-divisible channels"
    convs = arch.conv_layers
    h, w = arch.height, arch.width
    idx0, oh, ow = _unroll_indices(h, w, 3, 3, 1)
    # ---- first conv: integer GEMM on raw pixels (zero pad exact) ----
    (cin, cout, pool0) = convs[0]
    w1, tau1, g1 = params[0:3]
    pix = x_u8.reshape(h * w, cin).astype(jnp.int32)
    pix = jnp.concatenate([pix, jnp.zeros((1, cin), jnp.int32)], axis=0)
    patches = pix[idx0].reshape(oh * ow, 9 * cin)  # (pixels, k)
    acc = patches @ w1.astype(jnp.int32).T  # (pixels, f)
    if pool0:
        acc = _pool_i32(acc, oh, ow, cout)
        h, w = oh // 2, ow // 2
    else:
        h, w = oh, ow
    bits = pack.threshold_pack(acc, tau1, g1)  # (pixels, fw)
    # ---- packed conv blocks ----
    i = 3
    for (cin, cout, pool) in convs[1:]:
        cw = pack.words_for(cin)
        wp, corr, tau, g = params[i : i + 4]
        i += 4
        idx, oh, ow = _unroll_indices(h, w, 3, 3, 1)
        padded = jnp.concatenate([bits, jnp.zeros((1, cw), jnp.uint32)], axis=0)
        unrolled = padded[idx].reshape(oh * ow, 9 * cw)
        from .kernels.binary_gemm import binary_gemm

        acc = binary_gemm(unrolled, wp, 9 * cin) + corr
        if pool:
            acc = _pool_i32(acc, oh, ow, cout)
            h, w = oh // 2, ow // 2
        else:
            h, w = oh, ow
        bits = pack.threshold_pack(acc, tau, g)
    # ---- dense layers ----
    from .kernels.binary_gemm import binary_gemm

    flat = bits.reshape(1, -1)  # channel counts are 32-divisible => flat pack
    dims = [(arch.flat, arch.fc), (arch.fc, arch.fc), (arch.fc, arch.classes)]
    for li, (fin, fout) in enumerate(dims):
        wp, p1, p2 = params[i : i + 3]
        i += 3
        acc = binary_gemm(flat, wp, fin)
        if li < len(dims) - 1:
            flat = pack.threshold_pack(acc, p1, p2)
        else:
            return p1 * acc[0].astype(jnp.float32) + p2


def _pool_i32(acc: jnp.ndarray, oh: int, ow: int, f: int) -> jnp.ndarray:
    """2×2 stride-2 max pool on (oh*ow, f) int32, back to (pixels', f)."""
    t = acc.reshape(1, oh, ow, f)
    p = jax.lax.reduce_window(
        t,
        jnp.iinfo(jnp.int32).min,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
    return p.reshape(-1, f)


def cnn_binary_params(arch: CnnArch, layers) -> List[np.ndarray]:
    """Layer dicts → packed CNN param list (with precomputed padding
    corrections, mirroring rust `ConvLayer::build_correction`)."""
    from .kernels import ref

    convs = arch.conv_layers
    out = []
    h, w = arch.height, arch.width
    for li, ((cin, cout, pool), l) in enumerate(zip(convs, layers)):
        wf = np.where(np.asarray(l["w"], np.float32) >= 0, 1.0, -1.0)  # (f,3,3,cin)
        if li == 0:
            tau, g = fold_bn_threshold(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [wf.reshape(cout, -1).astype(np.int8), tau, g]
        else:
            # per-tap packed rows: (f, 9*cw)
            cw = (cin + 31) // 32
            wp = np.zeros((cout, 9 * cw), np.uint32)
            for t in range(9):
                wp[:, t * cw : (t + 1) * cw] = ref.pack_rows(
                    wf.reshape(cout, 9, cin)[:, t, :]
                )
            corr = _correction(wf, h, w)
            tau, g = fold_bn_threshold(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [wp, corr, tau, g]
        oh, ow = h, w  # 'same' conv
        if pool:
            oh, ow = oh // 2, ow // 2
        h, w = oh, ow
    n_fc = len(layers) - len(convs)
    for i, l in enumerate(layers[len(convs) :]):
        wf = np.where(np.asarray(l["w"], np.float32) >= 0, 1.0, -1.0)
        if i < n_fc - 1:
            tau, g = fold_bn_threshold(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [ref.pack_rows(wf), tau, g]
        else:
            a, b = fold_bn_affine(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [ref.pack_rows(wf), a, b]
    return out


def _correction(wf: np.ndarray, h: int, w: int) -> np.ndarray:
    """Zero-padding correction: Σ over OOB taps of the filter tap sums
    (paper §5.2), for 3×3 'same' convs."""
    f = wf.shape[0]
    tap_sum = wf.reshape(f, 9, -1).sum(axis=2)  # (f, 9)
    corr = np.zeros((h * w, f), np.int32)
    for oy in range(h):
        for ox in range(w):
            for ky in range(3):
                for kx in range(3):
                    iy, ix = oy + ky - 1, ox + kx - 1
                    if not (0 <= iy < h and 0 <= ix < w):
                        corr[oy * w + ox] += tap_sum[:, ky * 3 + kx].astype(np.int32)
    return corr


# ---------------------------------------------------------------------
# parameter initialization / conversion helpers
# ---------------------------------------------------------------------


def fold_bn_affine(gamma, beta, mean, var, eps):
    """BN → affine (a, b): y = a*x + b."""
    sigma = np.sqrt(np.asarray(var) + eps)
    a = np.asarray(gamma) / sigma
    b = np.asarray(beta) - np.asarray(gamma) * np.asarray(mean) / sigma
    return a.astype(np.float32), b.astype(np.float32)


def fold_bn_threshold(gamma, beta, mean, var, eps):
    """BN+sign → (tau, gamma_pos mask) (DESIGN.md §6)."""
    gamma = np.asarray(gamma, np.float32)
    sigma = np.sqrt(np.asarray(var, np.float32) + eps)
    tau = np.where(
        gamma == 0,
        np.where(np.asarray(beta) >= 0, -np.inf, np.inf),
        np.asarray(mean) - np.asarray(beta) * sigma / np.where(gamma == 0, 1, gamma),
    ).astype(np.float32)
    gpos = (gamma >= 0).astype(np.float32)
    return tau, gpos


def random_mlp_weights(arch: MlpArch, seed: int):
    """Random ±1 weights + plausible BN stats (for benches/tests)."""
    rng = np.random.default_rng(seed)
    layers = []
    for (fin, fout) in arch.dims:
        w = rng.choice([-1.0, 1.0], size=(fout, fin)).astype(np.float32)
        gamma = rng.uniform(0.5, 1.5, fout).astype(np.float32) * rng.choice(
            [-1.0, 1.0], fout
        ).astype(np.float32)
        beta = rng.uniform(-0.5, 0.5, fout).astype(np.float32)
        mean = (rng.uniform(-0.3, 0.3, fout) * np.sqrt(fin)).astype(np.float32)
        var = (rng.uniform(0.5, 2.0, fout) * fin).astype(np.float32)
        layers.append(dict(w=w, gamma=gamma, beta=beta, mean=mean, var=var, eps=1e-4))
    return layers


def mlp_float_params(layers) -> List[np.ndarray]:
    """Layer dicts → the flat float param list."""
    out = []
    for l in layers:
        a, b = fold_bn_affine(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
        out += [l["w"].astype(np.float32), a, b]
    return out


def mlp_binary_params(layers) -> List[np.ndarray]:
    """Layer dicts → the flat packed param list (pre-packed once — the
    Espresso load-time conversion)."""
    from .kernels import ref

    out = []
    n = len(layers)
    for i, l in enumerate(layers):
        w = np.where(l["w"] >= 0, 1, -1).astype(np.int8)
        if i == 0:
            tau, g = fold_bn_threshold(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [w, tau, g]
        elif i < n - 1:
            tau, g = fold_bn_threshold(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [ref.pack_rows(w.astype(np.float32)), tau, g]
        else:
            a, b = fold_bn_affine(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
            out += [ref.pack_rows(w.astype(np.float32)), a, b]
    return out


def random_cnn_weights(arch: CnnArch, seed: int):
    rng = np.random.default_rng(seed)
    layers = []
    for (cin, cout, _pool) in arch.conv_layers:
        w = rng.choice([-1.0, 1.0], size=(cout, 3, 3, cin)).astype(np.float32)
        fan = 9 * cin
        layers.append(_bn_layer(rng, w, cout, fan))
    dims = [(arch.flat, arch.fc), (arch.fc, arch.fc), (arch.fc, arch.classes)]
    for (fin, fout) in dims:
        w = rng.choice([-1.0, 1.0], size=(fout, fin)).astype(np.float32)
        layers.append(_bn_layer(rng, w, fout, fin))
    return layers


def _bn_layer(rng, w, f, fan):
    gamma = rng.uniform(0.5, 1.5, f).astype(np.float32) * rng.choice([-1.0, 1.0], f).astype(
        np.float32
    )
    return dict(
        w=w,
        gamma=gamma,
        beta=rng.uniform(-0.5, 0.5, f).astype(np.float32),
        mean=(rng.uniform(-0.3, 0.3, f) * np.sqrt(fan)).astype(np.float32),
        var=(rng.uniform(0.5, 2.0, f) * fan).astype(np.float32),
        eps=1e-4,
    )


def cnn_float_params(layers) -> List[np.ndarray]:
    out = []
    for l in layers:
        a, b = fold_bn_affine(l["gamma"], l["beta"], l["mean"], l["var"], l["eps"])
        out += [l["w"].astype(np.float32), a, b]
    return out
