"""L1 kernel correctness: Pallas binary GEMM / packing vs pure-numpy
oracles, with hypothesis sweeping shapes and values."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_gemm as bg
from compile.kernels import pack, ref

RNG = np.random.default_rng(1234)


def rand_pm1(*shape):
    return RNG.choice([-1.0, 1.0], size=shape).astype(np.float32)


# ---------------------------------------------------------------------
# reference self-consistency
# ---------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 300))
def test_ref_pack_unpack_roundtrip(k):
    x = rand_pm1(k)
    assert (ref.unpack_rows(ref.pack_rows(x), k) == x).all()


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 9), n=st.integers(1, 9), k=st.integers(1, 200))
def test_ref_packed_gemm_equals_float_gemm(m, n, k):
    a, b = rand_pm1(m, k), rand_pm1(n, k)
    got = ref.binary_gemm_packed(ref.pack_rows(a), ref.pack_rows(b), k)
    assert (got == ref.binary_gemm_float(a, b)).all()


def test_ref_popcount():
    xs = np.array([0, 1, 0xFFFFFFFF, 0x80000000, 0x0F0F0F0F], dtype=np.uint32)
    assert (ref.popcount(xs) == np.array([0, 1, 32, 1, 16])).all()


# ---------------------------------------------------------------------
# Pallas GEMM kernel vs reference
# ---------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 20),
    n=st.integers(1, 150),
    kw=st.integers(1, 8),
)
def test_pallas_gemm_matches_ref_shapes(m, n, kw):
    k = kw * 32
    a, b = rand_pm1(m, k), rand_pm1(n, k)
    pa, pb = ref.pack_rows(a), ref.pack_rows(b)
    got = np.asarray(bg.binary_gemm(jnp.asarray(pa), jnp.asarray(pb), k))
    assert (got == ref.binary_gemm_float(a, b)).all()


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 130))
def test_pallas_gemm_ragged_k(k):
    """k not a multiple of 32: tail padding must contribute nothing."""
    a, b = rand_pm1(3, k), rand_pm1(5, k)
    pa, pb = ref.pack_rows(a), ref.pack_rows(b)
    got = np.asarray(bg.binary_gemm(jnp.asarray(pa), jnp.asarray(pb), k))
    assert (got == ref.binary_gemm_float(a, b)).all()


def test_pallas_gemm_blocks_cover_non_divisible_mn():
    m, n, k = 13, 203, 96  # not multiples of the block sizes
    a, b = rand_pm1(m, k), rand_pm1(n, k)
    pa, pb = ref.pack_rows(a), ref.pack_rows(b)
    got = np.asarray(
        bg.binary_gemm(jnp.asarray(pa), jnp.asarray(pb), k, block_m=8, block_n=64)
    )
    assert got.shape == (m, n)
    assert (got == ref.binary_gemm_float(a, b)).all()


def test_pallas_gemm_extreme_inputs():
    k = 128
    ones = np.ones((2, k), np.float32)
    negs = -np.ones((2, k), np.float32)
    po, pn = ref.pack_rows(ones), ref.pack_rows(negs)
    out = np.asarray(bg.binary_gemm(jnp.asarray(po), jnp.asarray(pn), k))
    assert (out == -k).all()
    out2 = np.asarray(bg.binary_gemm(jnp.asarray(po), jnp.asarray(po), k))
    assert (out2 == k).all()


def test_vmem_accounting():
    # the BlockSpec schedule the DESIGN doc reasons about
    assert bg.vmem_bytes(8, 128, 128) == 4 * (8 * 128 + 128 * 128 + 8 * 128)
    assert bg.ops_per_grid_step(8, 128, 128) == 3 * 8 * 128 * 128


# ---------------------------------------------------------------------
# packing ops
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 6), kw=st.integers(1, 6))
def test_jnp_pack_matches_ref(m, kw):
    k = kw * 32
    x = RNG.standard_normal((m, k)).astype(np.float32)
    got = np.asarray(pack.pack_sign(jnp.asarray(x)))
    assert (got == ref.pack_rows(x)).all()


def test_jnp_pack_ragged():
    x = RNG.standard_normal((4, 45)).astype(np.float32)
    assert (np.asarray(pack.pack_sign(jnp.asarray(x))) == ref.pack_rows(x)).all()


def test_pallas_pack_matches_ref():
    x = RNG.standard_normal((16, 96)).astype(np.float32)
    got = np.asarray(pack.pack_sign_pallas(jnp.asarray(x), block_rows=8))
    assert (got == ref.pack_rows(x)).all()


def test_unpack_pm1_roundtrip():
    x = rand_pm1(3, 70)
    words = pack.pack_sign(jnp.asarray(x))
    back = np.asarray(pack.unpack_pm1(words, 70))
    assert (back == x).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 100))
def test_threshold_pack_matches_ref(n):
    x = RNG.integers(-50, 50, size=(2, n)).astype(np.int32)
    tau = RNG.standard_normal(n).astype(np.float32) * 10
    gpos = RNG.choice([0.0, 1.0], size=n).astype(np.float32)
    got = np.asarray(pack.threshold_pack(jnp.asarray(x), jnp.asarray(tau), jnp.asarray(gpos)))
    want_bits = ref.threshold_bits(x, tau, gpos > 0.5)
    assert (got == ref.pack_rows(np.where(want_bits, 1.0, -1.0))).all()


# ---------------------------------------------------------------------
# bit-plane first layer (paper Eq. 3)
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 300), n=st.integers(1, 20))
def test_bitplane_matvec_is_exact_integer_dot(k, n):
    x = RNG.integers(0, 256, size=k).astype(np.uint8)
    w = rand_pm1(n, k)
    wp = ref.pack_rows(w)
    got = np.asarray(pack.bitplane_matvec(jnp.asarray(x), jnp.asarray(wp), k))
    want = (x.astype(np.int64)[None, :] * w.astype(np.int64)).sum(axis=1)
    assert (got == want).all()


def test_bitplane_ref_matches_direct():
    x = RNG.integers(0, 256, size=100).astype(np.uint8)
    w = rand_pm1(7, 100)
    got = ref.bitplane_dot(x, w)
    want = (x.astype(np.int64)[None, :] * w.astype(np.int64)).sum(axis=1)
    assert (got == want).all()


def test_bitplane_extremes():
    x = np.full(64, 255, np.uint8)
    w = np.ones((1, 64), np.float32)
    assert ref.bitplane_dot(x, w)[0] == 255 * 64
    assert np.asarray(
        pack.bitplane_matvec(jnp.asarray(x), jnp.asarray(ref.pack_rows(w)), 64)
    )[0] == 255 * 64
