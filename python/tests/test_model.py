"""L2 model correctness: packed binary forward == float forward, CNN vs
numpy conv oracle, parameter specs consistent with actual arrays."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

ARCH = M.MlpArch(in_features=96, hidden=128, hidden_layers=2)


def _params(seed=0):
    layers = M.random_mlp_weights(ARCH, seed)
    pf = [jnp.asarray(p) for p in M.mlp_float_params(layers)]
    pb = [jnp.asarray(p) for p in M.mlp_binary_params(layers)]
    return layers, pf, pb


def test_param_specs_match_arrays():
    _, pf, pb = _params()
    for spec, arr in zip(M.bmlp_float_param_specs(ARCH), pf):
        assert tuple(spec[0]) == arr.shape
        assert np.dtype(spec[1]) == arr.dtype
    for spec, arr in zip(M.bmlp_binary_param_specs(ARCH), pb):
        assert tuple(spec[0]) == arr.shape
        assert np.dtype(spec[1]) == arr.dtype


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_binary_forward_equals_float_forward(seed):
    _, pf, pb = _params(seed)
    rng = np.random.default_rng(seed)
    for _ in range(4):
        x = rng.integers(0, 256, ARCH.in_features).astype(np.uint8)
        sf = np.asarray(M.bmlp_float_forward(ARCH, pf, jnp.asarray(x, jnp.float32)))
        sb = np.asarray(M.bmlp_binary_forward(ARCH, pb, jnp.asarray(x)))
        np.testing.assert_allclose(sf, sb, atol=3e-2)
        assert sf.argmax() == sb.argmax()


def test_binary_forward_jits_once():
    _, _, pb = _params()
    fwd = jnp.asarray  # silence lints
    f = jnp.asarray
    import jax

    jitted = jax.jit(lambda p, x: M.bmlp_binary_forward(ARCH, p, x))
    rng = np.random.default_rng(3)
    x = rng.integers(0, 256, ARCH.in_features).astype(np.uint8)
    a = np.asarray(jitted(pb, jnp.asarray(x)))
    b = np.asarray(jitted(pb, jnp.asarray(x)))
    np.testing.assert_array_equal(a, b)


def test_scores_are_affine_of_int_accumulators():
    layers, pf, pb = _params()
    rng = np.random.default_rng(4)
    x = rng.integers(0, 256, ARCH.in_features).astype(np.uint8)
    sb = np.asarray(M.bmlp_binary_forward(ARCH, pb, jnp.asarray(x)))
    assert sb.dtype == np.float32
    assert sb.shape == (10,)


# ---------------------------------------------------------------------
# CNN vs direct conv oracle (tiny arch)
# ---------------------------------------------------------------------

CARCH = M.CnnArch(height=8, width=8, stage_channels=(4, 8, 8), fc=16)


def test_cnn_forward_matches_numpy_oracle():
    layers = M.random_cnn_weights(CARCH, 5)
    params = [jnp.asarray(p) for p in M.cnn_float_params(layers)]
    rng = np.random.default_rng(6)
    x = rng.integers(0, 256, (8, 8, 3)).astype(np.float32)
    got = np.asarray(M.bcnn_float_forward(CARCH, params, jnp.asarray(x)))

    # numpy oracle replicating conv->pool->affine->sign blocks
    h = x
    idx = 0
    flat_params = M.cnn_float_params(layers)
    for (cin, cout, pool) in CARCH.conv_layers:
        w, a, b = flat_params[idx : idx + 3]
        idx += 3
        h = ref.conv2d_ref(h, w, pad=1)
        if pool:
            h = ref.maxpool2d_ref(h, 2, 2)
        h = a * h + b
        h = np.where(h >= 0, 1.0, -1.0).astype(np.float32)
    v = h.reshape(-1)
    dims = [(CARCH.flat, CARCH.fc), (CARCH.fc, CARCH.fc), (CARCH.fc, CARCH.classes)]
    for i, _ in enumerate(dims):
        w, a, b = flat_params[idx : idx + 3]
        idx += 3
        acc = w @ v
        y = a * acc + b
        v = np.where(y >= 0, 1.0, -1.0).astype(np.float32) if i < 2 else y
    np.testing.assert_allclose(got, v, rtol=1e-4, atol=1e-3)


def test_cnn_flat_dim():
    assert CARCH.flat == 1 * 1 * 8
    assert M.CnnArch().flat == 4 * 4 * 512


def test_fold_helpers_consistent():
    rng = np.random.default_rng(7)
    f = 32
    gamma = rng.uniform(-2, 2, f).astype(np.float32)
    gamma[np.abs(gamma) < 0.1] = 1.0
    beta = rng.uniform(-1, 1, f).astype(np.float32)
    mean = rng.uniform(-5, 5, f).astype(np.float32)
    var = rng.uniform(0.5, 3, f).astype(np.float32)
    eps = 1e-4
    a, b = M.fold_bn_affine(gamma, beta, mean, var, eps)
    tau, gpos = M.fold_bn_threshold(gamma, beta, mean, var, eps)
    xs = rng.integers(-100, 100, size=(200, f)).astype(np.float32)
    affine_sign = (a * xs + b) >= 0
    thresh = np.where(gpos > 0.5, xs >= tau, xs <= tau)
    # away from the boundary the two folds agree exactly
    boundary = np.abs(a * xs + b) < 1e-3
    agree = affine_sign == thresh
    assert (agree | boundary).all()
