"""Exporter tests: .esp / .espdata byte layout and the input-normalization
absorption math."""

import struct

import numpy as np
import pytest

from compile import convert, model as M


def _read_u32(b, off):
    return struct.unpack_from("<I", b, off)[0], off + 4


def test_esp_header_layout(tmp_path):
    w = np.ones((4, 8), np.float32)
    rec = convert.dense_layer(w, sign=True, bitplane_first=True)
    path = tmp_path / "m.esp"
    convert.write_esp(str(path), "hdr-test", (1, 8, 1), convert.INPUT_BYTES, [rec])
    b = path.read_bytes()
    assert b[:4] == b"ESP1"
    off = 4
    ver, off = _read_u32(b, off)
    assert ver == 1
    nlen, off = _read_u32(b, off)
    assert b[off : off + nlen] == b"hdr-test"
    off += nlen
    m, off = _read_u32(b, off)
    n, off = _read_u32(b, off)
    l, off = _read_u32(b, off)
    assert (m, n, l) == (1, 8, 1)
    assert b[off] == convert.INPUT_BYTES
    off += 1
    nl, off = _read_u32(b, off)
    assert nl == 1
    assert b[off] == 1  # dense tag


def test_dense_record_flags():
    w = np.ones((2, 3), np.float32)
    bn = dict(eps=1e-4, gamma=[1, 1], beta=[0, 0], mean=[0, 0], var=[1, 1])
    rec = convert.dense_layer(w, sign=True, bn=bn, bitplane_first=True)
    # tag, in, out, flags
    assert rec[0] == 1
    in_f = struct.unpack_from("<I", rec, 1)[0]
    out_f = struct.unpack_from("<I", rec, 5)[0]
    flags = rec[9]
    assert (in_f, out_f) == (3, 2)
    assert flags == 0b111


def test_conv_record_roundtrip_fields():
    w = np.ones((4, 3, 3, 2), np.float32)
    rec = convert.conv_layer(w, stride=1, pad=1, sign=True, pool=(2, 2))
    assert rec[0] == 2
    vals = struct.unpack_from("<6I", rec, 1)
    assert vals == (2, 4, 3, 3, 1, 1)  # cin, f, kh, kw, stride, pad
    flags = rec[25]
    assert flags & 0b101 == 0b101  # sign + pool, no bn


def test_espdata_layout(tmp_path):
    imgs = np.arange(2 * 6, dtype=np.uint8).reshape(2, 6)
    labels = np.array([3, 9], np.uint8)
    p = tmp_path / "d.espdata"
    convert.write_espdata(str(p), imgs, labels, (1, 6, 1))
    b = p.read_bytes()
    assert b[:4] == b"ESPD"
    count = struct.unpack_from("<I", b, 20)[0]
    assert count == 2
    assert b[24 : 24 + 12] == imgs.tobytes()
    assert b[36:38] == labels.tobytes()


def test_absorb_input_normalization_math():
    rng = np.random.default_rng(8)
    n_out, n_in = 5, 12
    w = rng.choice([-1.0, 1.0], size=(n_out, n_in)).astype(np.float32)
    bn = dict(
        gamma=rng.uniform(0.5, 1.5, n_out).astype(np.float32),
        beta=rng.uniform(-0.5, 0.5, n_out).astype(np.float32),
        mean=rng.uniform(-2, 2, n_out).astype(np.float32),
        var=rng.uniform(0.5, 2, n_out).astype(np.float32),
        eps=1e-4,
    )
    adj = convert.absorb_input_normalization(w, bn)
    x = rng.integers(0, 256, n_in).astype(np.float32)
    x_norm = x / convert.PIX_SCALE - 1.0
    acc_norm = w @ x_norm
    acc_raw = w @ x
    y_norm = M.fold_bn_affine(bn["gamma"], bn["beta"], bn["mean"], bn["var"], bn["eps"])
    y1 = y_norm[0] * acc_norm + y_norm[1]
    y_adj = M.fold_bn_affine(adj["gamma"], adj["beta"], adj["mean"], adj["var"], adj["eps"])
    y2 = y_adj[0] * acc_raw + y_adj[1]
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


def test_write_espdata_validates_shape(tmp_path):
    imgs = np.zeros((2, 5), np.uint8)
    with pytest.raises(AssertionError):
        convert.write_espdata(str(tmp_path / "x"), imgs, np.zeros(2, np.uint8), (1, 6, 1))
