"""Packed binary CNN (Pallas conv path) vs the float CNN model."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

ARCH = M.CnnArch(height=16, width=16, stage_channels=(32, 32, 64), fc=64)


def _params(seed=0):
    layers = M.random_cnn_weights(ARCH, seed)
    pf = [jnp.asarray(p) for p in M.cnn_float_params(layers)]
    pb = [jnp.asarray(p) for p in M.cnn_binary_params(ARCH, layers)]
    return pf, pb


def test_param_specs_match_arrays():
    _, pb = _params()
    specs = M.bcnn_binary_param_specs(ARCH)
    assert len(specs) == len(pb)
    for (shape, dtype), arr in zip(specs, pb):
        assert tuple(shape) == arr.shape
        assert np.dtype(dtype) == arr.dtype


@pytest.mark.parametrize("seed", [0, 1])
def test_binary_cnn_matches_float(seed):
    pf, pb = _params(seed)
    rng = np.random.default_rng(seed + 10)
    for _ in range(2):
        x = rng.integers(0, 256, (16, 16, 3)).astype(np.uint8)
        sf = np.asarray(M.bcnn_float_forward(ARCH, pf, jnp.asarray(x, jnp.float32)))
        sb = np.asarray(M.bcnn_binary_forward(ARCH, pb, jnp.asarray(x)))
        np.testing.assert_allclose(sf, sb, atol=5e-2)
        assert sf.argmax() == sb.argmax()


def test_unroll_indices_padding_rows():
    idx, oh, ow = M._unroll_indices(4, 4, 3, 3, 1)
    assert (oh, ow) == (4, 4)
    # corner (0,0): taps above/left point at the zero row (16)
    assert idx[0, 0] == 16 and idx[0, 4] == 0
    # interior pixel (1,1) has no padding taps
    assert (idx[5] != 16).all()


def test_correction_zero_in_interior():
    wf = np.ones((4, 3, 3, 8), np.float32)
    corr = M._correction(wf, 5, 5)
    interior = corr.reshape(5, 5, 4)[1:4, 1:4]
    assert (interior == 0).all()
    # corner corrects 5 OOB taps * 8 channels
    assert corr.reshape(5, 5, 4)[0, 0, 0] == 5 * 8


def test_requires_divisible_channels():
    bad = M.CnnArch(height=8, width=8, stage_channels=(8, 8, 24), fc=16)
    layers = M.random_cnn_weights(bad, 0)
    pb = [jnp.asarray(p) for p in M.cnn_binary_params(bad, layers)]
    x = jnp.zeros((8, 8, 3), jnp.uint8)
    with pytest.raises(AssertionError):
        M.bcnn_binary_forward(bad, pb, x)


def test_artifact_lowers(tmp_path):
    arch = M.CnnArch(height=8, width=8, stage_channels=(32, 32, 32), fc=32)
    fn, specs = aot.bcnn_binary_artifact(arch)
    aot.write_artifact(str(tmp_path), "bcnn_bin", fn, specs)
    text = (tmp_path / "bcnn_bin.hlo.txt").read_text()
    assert "ENTRY" in text
    assert "popcnt" in text or "population" in text.lower()
