"""Trainer tests: STE gradient semantics, learning progress, export
compatibility of the produced layer dicts."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M, train as T


def test_ste_sign_forward():
    x = jnp.asarray([-2.0, -0.0, 0.0, 0.5])
    y = np.asarray(T.ste_sign(x))
    assert (y == np.array([-1.0, 1.0, 1.0, 1.0])).all()


def test_ste_gradient_is_clipped_identity():
    g = jax.grad(lambda x: T.ste_sign(x).sum())(jnp.asarray([-2.0, -0.5, 0.5, 2.0]))
    assert (np.asarray(g) == np.array([0.0, 1.0, 1.0, 0.0])).all()


def test_dataset_is_learnable_and_balanced():
    x, y = T.make_dataset(100, seed=3)
    assert x.shape == (100, 784)
    assert x.dtype == np.uint8
    counts = np.bincount(y, minlength=10)
    assert (counts == 10).all()


def test_training_improves_accuracy():
    layers, (xte, yte), acc = T.train_bmlp(
        hidden=64,
        hidden_layers=1,
        n_train=800,
        n_test=200,
        epochs=6,
        batch=100,
        log=lambda *_: None,
    )
    assert acc > 0.5, f"binary MLP should learn the blob task, got {acc}"
    assert len(layers) == 2
    for l in layers:
        assert set(l) == {"w", "gamma", "beta", "mean", "var", "eps"}
        assert np.isin(l["w"], [-1.0, 1.0]).all(), "exported weights are ±1"


def test_trained_layers_feed_the_binary_model():
    layers, (xte, yte), _ = T.train_bmlp(
        hidden=64,
        hidden_layers=1,
        n_train=400,
        n_test=100,
        epochs=3,
        batch=100,
        log=lambda *_: None,
    )
    arch = M.MlpArch(hidden=64, hidden_layers=1)
    # exported raw-pixel form: adjust first layer as convert does
    from compile import convert

    adj = convert.absorb_input_normalization(
        layers[0]["w"], {k: layers[0][k] for k in ("gamma", "beta", "mean", "var", "eps")}
    )
    layers_raw = [dict(layers[0], **adj)] + layers[1:]
    pf = [jnp.asarray(p) for p in M.mlp_float_params(layers_raw)]
    pb = [jnp.asarray(p) for p in M.mlp_binary_params(layers_raw)]
    # binary/float agreement on raw pixels + accuracy sanity vs trainer
    correct = 0
    for i in range(50):
        x = xte[i].astype(np.uint8)
        sf = np.asarray(M.bmlp_float_forward(arch, pf, jnp.asarray(x, jnp.float32)))
        sb = np.asarray(M.bmlp_binary_forward(arch, pb, jnp.asarray(x)))
        np.testing.assert_allclose(sf, sb, atol=3e-2)
        correct += int(sb.argmax() == yte[i])
    assert correct / 50 > 0.4
