"""AOT bridge tests: lowering emits parseable HLO text + correct meta."""

import os

import jax.numpy as jnp

from compile import aot, model as M


def test_smoke_artifact_lowers(tmp_path):
    aot.write_artifact(str(tmp_path), "smoke", *aot.smoke_artifact())
    text = (tmp_path / "smoke.hlo.txt").read_text()
    assert "ENTRY" in text and "HloModule" in text
    meta = (tmp_path / "smoke.meta").read_text().splitlines()
    assert meta[0] == "artifact smoke"
    assert meta[1] == "args 2"
    assert meta[2] == "arg float32 2,2"


def test_small_binary_mlp_lowers_with_pallas(tmp_path):
    arch = M.MlpArch(in_features=96, hidden=64, hidden_layers=1)
    fn, specs = aot.bmlp_binary_artifact(arch)
    aot.write_artifact(str(tmp_path), "tiny_binary", fn, specs)
    text = (tmp_path / "tiny_binary.hlo.txt").read_text()
    # the packed path must lower popcount into the module
    assert "popcnt" in text or "population" in text.lower()
    meta = (tmp_path / "tiny_binary.meta").read_text().splitlines()
    # w1 int8 + tau + gpos, (wp, a, b) for output, + x
    assert meta[1] == f"args {len(specs)}"
    assert any("uint8" in l for l in meta)
    assert any("uint32" in l for l in meta)


def test_float_cnn_lowers(tmp_path):
    arch = M.CnnArch(height=8, width=8, stage_channels=(4, 4, 8), fc=16)
    fn, specs = aot.bcnn_float_artifact(arch)
    aot.write_artifact(str(tmp_path), "tiny_cnn", fn, specs)
    text = (tmp_path / "tiny_cnn.hlo.txt").read_text()
    assert "convolution" in text
    assert "ENTRY" in text


def test_meta_arg_order_matches_specs(tmp_path):
    arch = M.MlpArch(in_features=32, hidden=32, hidden_layers=1)
    fn, specs = aot.bmlp_float_artifact(arch)
    aot.write_artifact(str(tmp_path), "order", fn, specs)
    lines = (tmp_path / "order.meta").read_text().splitlines()[2:]
    assert len(lines) == len(specs)
    for line, (shape, dtype) in zip(lines, specs):
        _, dt, dims = line.split()
        assert dt == str(jnp.dtype(dtype).name) or dt in dt
        assert dims == ",".join(str(d) for d in shape)
