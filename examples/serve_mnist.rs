//! End-to-end serving driver (the repo's headline validation run).
//!
//! Loads the python-trained binary MLP, registers native binary / native
//! float / XLA engines with the coordinator, starts the TCP server, and
//! replays a closed-loop request trace from concurrent clients. Reports
//! per-engine latency percentiles, throughput, accuracy on the real test
//! set, and the dynamic-batching effect (max_batch 1 vs 8).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_mnist
//! ```

use espresso::coordinator::{tcp, BatchConfig, Coordinator};
use espresso::data;
use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::{argmax, Network};
use espresso::runtime::{artifact_exists, NativeEngine, XlaEngine, XlaModelKind};
use espresso::util::stats::{fmt_ns, Summary};
use espresso::util::Timer;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 150;

fn main() -> anyhow::Result<()> {
    let esp = Path::new("artifacts/bmlp_trained.esp");
    let ds_path = Path::new("artifacts/testset_mnist.espdata");
    anyhow::ensure!(
        esp.exists() && ds_path.exists(),
        "trained artifacts missing — run `make artifacts` first"
    );
    let spec = ModelSpec::load(esp)?;
    let ds = Arc::new(data::load_espdata(ds_path)?);
    println!("model {} | test set: {} images", spec.name, ds.len());

    for (label, max_batch) in [("max_batch=1 (paper mode)", 1usize), ("max_batch=8", 8)] {
        println!("\n=== {label} ===");
        run_trace(&spec, &ds, max_batch)?;
    }
    Ok(())
}

fn run_trace(spec: &ModelSpec, ds: &Arc<data::Dataset>, max_batch: usize) -> anyhow::Result<()> {
    let coord = Arc::new(Coordinator::new(BatchConfig {
        max_batch,
        max_wait: Duration::from_micros(300),
        ..BatchConfig::default()
    }));
    coord.register(
        "opt",
        Arc::new(NativeEngine::new(
            Network::<u64>::from_spec(spec, Backend::Binary)?,
            "opt",
        )),
    );
    coord.register(
        "float",
        Arc::new(NativeEngine::new(
            Network::<u64>::from_spec(spec, Backend::Float)?,
            "float",
        )),
    );
    let dir = Path::new("artifacts");
    if artifact_exists(dir, "bmlp_binary_small") {
        match XlaEngine::load(dir, "bmlp_binary_small", spec, XlaModelKind::MlpBinary) {
            Ok(e) => coord.register("xla", Arc::new(e)),
            Err(e) => println!("(xla engine unavailable: {e})"),
        }
    }

    // the front end (Linux-only): event-driven epoll loops, one per
    // core, each accepting on its own SO_REUSEPORT listener
    let opts = tcp::ServeOptions::default();
    println!(
        "front end: {:?} ({} io loops)",
        opts.io_model,
        opts.effective_io_loops()
    );
    let server = tcp::serve(coord.clone(), "127.0.0.1:0", opts)?;
    let addr = server.addr().to_string();

    for model in coord.models() {
        let wall = Timer::start();
        let (lat_ns, correct, total) = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for c in 0..CLIENTS {
                let addr = addr.clone();
                let ds = ds.clone();
                let model = model.clone();
                handles.push(s.spawn(move || {
                    let mut client = tcp::Client::connect(&addr).unwrap();
                    let mut lats = Vec::with_capacity(REQS_PER_CLIENT);
                    let mut correct = 0usize;
                    for r in 0..REQS_PER_CLIENT {
                        let i = (c * REQS_PER_CLIENT + r) % ds.len();
                        let t = Timer::start();
                        let scores = client.predict(&model, &ds.images[i].data).unwrap();
                        lats.push(t.elapsed_ns() as f64);
                        if argmax(&scores) == ds.labels[i] {
                            correct += 1;
                        }
                    }
                    (lats, correct)
                }));
            }
            let mut all = Vec::new();
            let mut correct = 0;
            for h in handles {
                let (lats, c) = h.join().unwrap();
                all.extend(lats);
                correct += c;
            }
            let total = all.len();
            (all, correct, total)
        });
        let wall_s = wall.elapsed_s();
        let summary = Summary::from(&lat_ns);
        println!(
            "{model:<8} {total} reqs x{CLIENTS} clients | p50 {} p95 {} p99 {} | {:.0} req/s | acc {:.1}%",
            fmt_ns(summary.p50),
            fmt_ns(summary.p95),
            fmt_ns(summary.p99),
            total as f64 / wall_s,
            100.0 * correct as f64 / total as f64
        );
    }
    println!("\nserver-side metrics:\n{}", coord.metrics.render());
    Ok(())
}
