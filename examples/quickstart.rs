//! Quickstart: load a trained binary MLP from `.esp`, classify a few
//! images, and compare the binary-optimized engine against the float
//! comparator (paper Table 2 in miniature).
//!
//! Run after `make artifacts`:
//! ```sh
//! cargo run --release --example quickstart
//! ```

use espresso::data;
use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::{argmax, bmlp_spec, Network};
use espresso::util::rng::Rng;
use espresso::util::Timer;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // prefer the python-trained model; fall back to random weights
    let esp = Path::new("artifacts/bmlp_trained.esp");
    let spec = if esp.exists() {
        println!("loading trained model {esp:?}");
        ModelSpec::load(esp)?
    } else {
        println!("no trained artifacts — using random weights (run `make artifacts`)");
        bmlp_spec(&mut Rng::new(1), 256, 2)
    };

    // the same parameters power both execution variants
    let opt = Network::<u64>::from_spec(&spec, Backend::Binary)?;
    let float = Network::<u64>::from_spec(&spec, Backend::Float)?;
    println!("model: {} | layers:", spec.name);
    for d in opt.describe() {
        println!("  {d}");
    }
    let mem = opt.memory_report();
    println!(
        "parameters: {:.2} MB float -> {:.3} MB packed ({:.1}x smaller)\n",
        mem.total_float() as f64 / 1e6,
        mem.total_packed() as f64 / 1e6,
        mem.saving()
    );

    // classify test images (exported by the trainer when available)
    let ds_path = Path::new("artifacts/testset_mnist.espdata");
    let ds = if ds_path.exists() {
        data::load_espdata(ds_path)?
    } else {
        data::synth(spec.input_shape, 10, 32, 7)
    };

    let n = 32.min(ds.len());
    let mut agree = 0;
    let mut correct = 0;
    let t_opt = Timer::start();
    let preds_opt: Vec<usize> = (0..n)
        .map(|i| argmax(&opt.predict_bytes(&ds.images[i])))
        .collect();
    let opt_ms = t_opt.elapsed_ms();
    let t_float = Timer::start();
    let preds_float: Vec<usize> = (0..n)
        .map(|i| argmax(&float.predict_bytes(&ds.images[i])))
        .collect();
    let float_ms = t_float.elapsed_ms();

    for i in 0..n {
        if preds_opt[i] == preds_float[i] {
            agree += 1;
        }
        if preds_opt[i] == ds.labels[i] {
            correct += 1;
        }
    }
    println!("binary-optimized: {n} images in {opt_ms:.2} ms ({:.3} ms/img)", opt_ms / n as f64);
    println!("float comparator: {n} images in {float_ms:.2} ms ({:.3} ms/img)", float_ms / n as f64);
    println!("engine agreement: {agree}/{n} (numerically equivalent networks)");
    println!("accuracy:         {correct}/{n}");
    println!("speedup:          {:.1}x", float_ms / opt_ms);
    Ok(())
}
