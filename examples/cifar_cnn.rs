//! Binary CNN forward propagation across engines (paper §6.3 / Table 3
//! in example form).
//!
//! Builds the CIFAR-10 VGG-like BCNN (optionally scaled by `--width`),
//! runs single-image forwards through the float comparator and the
//! binary-optimized engine, checks they agree, and prints the timing and
//! memory picture. Use `--width 1.0` for the paper-size network.
//!
//! ```sh
//! cargo run --release --example cifar_cnn -- --width 0.25
//! ```

use espresso::data;
use espresso::layers::Backend;
use espresso::net::{argmax, bcnn_spec, Network};
use espresso::util::cli::Args;
use espresso::util::rng::Rng;
use espresso::util::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(&[]);
    let width = args.get_parse_or("width", 0.25f32);
    let count = args.get_parse_or("count", 8usize);
    let mut rng = Rng::new(args.get_parse_or("seed", 9u64));

    println!("building BCNN width={width} (paper arch at 1.0: 2x128C3-MP2-2x256C3-MP2-2x512C3-MP2-1024FC-1024FC-10)");
    let spec = bcnn_spec(&mut rng, width);
    let opt = Network::<u64>::from_spec(&spec, Backend::Binary)?;
    let float = Network::<u64>::from_spec(&spec, Backend::Float)?;
    for d in opt.describe() {
        println!("  {d}");
    }
    let mem = opt.memory_report();
    println!(
        "parameters: {:.2} MB float -> {:.2} MB packed ({:.1}x)\n",
        mem.total_float() as f64 / 1e6,
        mem.total_packed() as f64 / 1e6,
        mem.saving()
    );

    let ds = data::synth_cifar(count, 21);
    // warmup
    let _ = opt.predict_bytes(&ds.images[0]);
    let _ = float.predict_bytes(&ds.images[0]);

    let mut agree = 0;
    let t_opt = Timer::start();
    let preds_opt: Vec<usize> = ds.images.iter().map(|i| argmax(&opt.predict_bytes(i))).collect();
    let opt_ms = t_opt.elapsed_ms();
    let t_float = Timer::start();
    let preds_float: Vec<usize> = ds
        .images
        .iter()
        .map(|i| argmax(&float.predict_bytes(i)))
        .collect();
    let float_ms = t_float.elapsed_ms();
    for (a, b) in preds_opt.iter().zip(&preds_float) {
        if a == b {
            agree += 1;
        }
    }

    println!(
        "float (CPU comparator): {:.2} ms/image",
        float_ms / count as f64
    );
    println!(
        "binary-optimized:       {:.2} ms/image  ({:.1}x speedup)",
        opt_ms / count as f64,
        float_ms / opt_ms
    );
    println!("prediction agreement:   {agree}/{count}");

    // batched forward: the whole set flows through ONE GEMM per layer,
    // and results stay bit-identical to the per-image loop above
    let refs: Vec<&espresso::tensor::Tensor<u8>> = ds.images.iter().collect();
    let t_batch = Timer::start();
    let batched = opt.predict_batch_bytes(&refs);
    let batch_ms = t_batch.elapsed_ms();
    let batch_agree = batched
        .iter()
        .zip(&preds_opt)
        .filter(|(scores, &p)| argmax(scores) == p)
        .count();
    println!(
        "batched (B={count}):         {:.2} ms/image  ({:.1}x vs per-image loop), agreement {batch_agree}/{count}",
        batch_ms / count as f64,
        opt_ms / batch_ms
    );
    println!(
        "\npaper Table 3 (GTX 960): CPU 85.2 ms | GPU 5.2 ms (16x) | GPU^opt 1.0 ms (85x)"
    );
    println!("(this testbed reproduces the float-vs-binary *structure*; see EXPERIMENTS.md)");
    Ok(())
}
