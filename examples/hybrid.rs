//! Hybrid-network demo (paper §3 "Hybrid DNNs"): the same network with
//! per-layer backend assignments, all combinations agreeing numerically,
//! with a small timing scan showing where the binary layers pay off.
//!
//! ```sh
//! cargo run --release --example hybrid
//! ```

use espresso::data;
use espresso::format::ModelSpec;
use espresso::layers::Backend;
use espresso::net::{bmlp_spec, Network};
use espresso::util::rng::Rng;
use espresso::util::Timer;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let esp = Path::new("artifacts/bmlp_trained.esp");
    let spec = if esp.exists() {
        ModelSpec::load(esp)?
    } else {
        bmlp_spec(&mut Rng::new(3), 256, 2)
    };
    let mut net = Network::<u64>::from_spec(&spec, Backend::Binary)?;
    let n_layers = net.layer_count();
    println!("{} layers; scanning all {} backend assignments\n", n_layers, 1 << n_layers);

    let ds = data::synth(net.input_shape, 10, 64, 5);
    let reference: Vec<Vec<f32>> = ds.images.iter().map(|i| net.predict_bytes(i)).collect();

    println!(
        "{:<24} {:>12} {:>10}",
        "backends (B=binary,F=float)", "ms/image", "agree"
    );
    for mask in 0..(1u32 << n_layers) {
        let backends: Vec<Backend> = (0..n_layers)
            .map(|i| {
                if mask & (1 << i) != 0 {
                    Backend::Float
                } else {
                    Backend::Binary
                }
            })
            .collect();
        net.set_backends(&backends);
        // warmup + agreement check
        let mut agree = 0;
        for (img, want) in ds.images.iter().zip(&reference) {
            let got = net.predict_bytes(img);
            if got
                .iter()
                .zip(want)
                .all(|(a, b)| (a - b).abs() < 1e-2)
            {
                agree += 1;
            }
        }
        let t = Timer::start();
        for img in &ds.images {
            let _ = net.predict_bytes(img);
        }
        let ms = t.elapsed_ms() / ds.len() as f64;
        let label: String = backends
            .iter()
            .map(|b| if *b == Backend::Binary { 'B' } else { 'F' })
            .collect();
        println!("{label:<24} {ms:>12.4} {agree:>7}/{}", ds.len());
    }
    println!(
        "\nevery mix stays numerically equivalent (paper §3); at this small \
         width the float first layer can win — see the FIG-W sweep for the \
         crossover."
    );
    Ok(())
}
